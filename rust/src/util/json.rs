//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` plus
//! report emission: objects, arrays, strings with escapes, numbers, bools,
//! null. Parsing is recursive-descent over bytes; no external deps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value. Object keys keep sorted order (BTreeMap) — manifest
/// consumers index by key, never by position.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    /// Indexing helper: `get("a")?.get("b")?` style navigation.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing key {key:?}"))
    }

    /// Optional key lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Serialize compactly (stable key order).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, got {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: manifest never emits them,
                            // but handle for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.i..self.i + 4],
                                )?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| {
                                anyhow!("bad unicode escape")
                            })?);
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation
                    // bytes verbatim.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    let sl = &self.b[start..start + len];
                    out.push_str(std::str::from_utf8(sl)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number {text:?} at offset {start}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap(),
            &Json::Bool(false)
        );
    }

    #[test]
    fn parses_unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"o":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld 😀\"").unwrap();
        assert_eq!(v, Json::Str("héllo wörld 😀".into()));
    }

    #[test]
    fn builders() {
        let j = obj(vec![("x", num(1.0)), ("y", arr([s("a"), s("b")]))]);
        assert_eq!(j.dump(), r#"{"x":1,"y":["a","b"]}"#);
    }
}
