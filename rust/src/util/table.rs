//! Aligned-text and Markdown table rendering for experiment reports.
//!
//! Every experiment module emits its paper table/figure through this type so
//! EXPERIMENTS.md sections and terminal output share one formatter.

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Format an f64 with `prec` decimals (handles NaN gracefully).
    pub fn fmt(v: f64, prec: usize) -> String {
        if v.is_nan() {
            "-".to_string()
        } else {
            format!("{v:.prec$}")
        }
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Monospace rendering for terminals.
    pub fn render_text(&self) -> String {
        let w = self.widths();
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored Markdown rendering for EXPERIMENTS.md.
    pub fn render_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.headers.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Render an ASCII sparkline-esque series (for loss curves in reports).
pub fn series_line(label: &str, xs: &[f64]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if xs.is_empty() {
        return format!("{label}: (empty)");
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let line: String = xs
        .iter()
        .map(|x| GLYPHS[(((x - lo) / span) * 7.0).round() as usize])
        .collect();
    format!("{label}: {line}  [min {lo:.4}, max {hi:.4}]")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        t
    }

    #[test]
    fn text_alignment() {
        let out = sample().render_text();
        assert!(out.contains("a    bb"));
        assert!(out.contains("333  4"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().render_markdown();
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 333 | 4 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        Table::new("T", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_nan() {
        assert_eq!(Table::fmt(f64::NAN, 2), "-");
        assert_eq!(Table::fmt(1.23456, 2), "1.23");
    }

    #[test]
    fn sparkline_monotone() {
        let s = series_line("x", &[0.0, 1.0, 2.0, 3.0]);
        assert!(s.contains('▁') && s.contains('█'));
    }
}
