//! Bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets declare `harness = false` and drive this runner:
//! warmup, timed iterations, mean/p50/p95 and optional throughput, with a
//! `--filter` CLI matching criterion's substring selection.
//!
//! Cases registered through [`Bench::bench_case`] carry machine-readable
//! metadata (op, shape, threads) and can be persisted to a JSON scoreboard
//! with [`Bench::write_json`] — `BENCH_native.json` is how the native
//! runtime's perf trajectory is tracked across PRs instead of eyeballed.

use std::path::Path;
use std::time::Instant;

use crate::util::json::{self, Json};

use super::timer::Stats;

/// Machine-readable identity of one bench case (for the JSON scoreboard).
#[derive(Debug, Clone)]
pub struct CaseMeta {
    /// Operation family, e.g. "matmul", "train_step".
    pub op: String,
    /// Shape tag, e.g. "1024x192x768" or a config name.
    pub shape: String,
    /// ExecCtx thread count the case ran with.
    pub threads: usize,
}

impl CaseMeta {
    pub fn new(op: &str, shape: &str, threads: usize) -> CaseMeta {
        CaseMeta { op: op.into(), shape: shape.into(), threads }
    }
}

/// One finished measurement.
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    /// Throughput (units/s) when the case declared units per iteration.
    pub thr: Option<f64>,
    /// Present for cases registered through [`Bench::bench_case`].
    pub meta: Option<CaseMeta>,
}

pub struct Bench {
    filter: Option<String>,
    pub results: Vec<BenchResult>,
    warmup_iters: usize,
    iters: usize,
}

impl Bench {
    pub fn from_env() -> Bench {
        // `cargo bench -- --filter foo --iters 20`
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut filter = None;
        let mut iters = 10;
        let mut warmup = 2;
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--filter" if i + 1 < argv.len() => {
                    filter = Some(argv[i + 1].clone());
                    i += 1;
                }
                "--iters" if i + 1 < argv.len() => {
                    iters = argv[i + 1].parse().unwrap_or(10);
                    i += 1;
                }
                "--warmup" if i + 1 < argv.len() => {
                    warmup = argv[i + 1].parse().unwrap_or(2);
                    i += 1;
                }
                // `cargo bench` passes --bench; ignore unknown args.
                _ => {}
            }
            i += 1;
        }
        Bench { filter, results: vec![], warmup_iters: warmup, iters }
    }

    pub fn with_iters(iters: usize, warmup: usize) -> Bench {
        Bench { filter: None, results: vec![], warmup_iters: warmup, iters }
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter
            .as_ref()
            .map(|f| name.contains(f.as_str()))
            .unwrap_or(true)
    }

    /// Time `f` (called once per iteration). `units_per_iter`, if nonzero,
    /// reports throughput (units/s) — tokens, bytes, elements.
    pub fn bench<T>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        f: impl FnMut() -> T,
    ) {
        self.run_case(name, None, units_per_iter, f);
    }

    /// [`Bench::bench`] with scoreboard metadata: the case lands in
    /// [`Bench::write_json`] output keyed by (op, shape, threads).
    pub fn bench_case<T>(
        &mut self,
        name: &str,
        meta: CaseMeta,
        units_per_iter: f64,
        f: impl FnMut() -> T,
    ) {
        self.run_case(name, Some(meta), units_per_iter, f);
    }

    fn run_case<T>(
        &mut self,
        name: &str,
        meta: Option<CaseMeta>,
        units_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let stats = Stats::from_samples(&samples);
        let thr = if units_per_iter > 0.0 {
            Some(units_per_iter / stats.mean)
        } else {
            None
        };
        println!("{}", render_line(name, &stats, thr));
        self.results.push(BenchResult {
            name: name.to_string(),
            stats,
            thr,
            meta,
        });
    }

    /// Record an externally-measured sample set (e.g. per-step times from a
    /// training loop) under this bench's reporting format.
    pub fn record(&mut self, name: &str, samples: &[f64], units: f64) {
        self.record_with_meta(name, None, samples, units);
    }

    /// [`Bench::record`] with scoreboard metadata: externally-measured
    /// samples that should land in the JSON scoreboard (e.g. the realized
    /// comm/compute overlap fraction, encoded in seconds so `ns_per_iter`
    /// carries fraction × 1e9).
    pub fn record_case(
        &mut self,
        name: &str,
        meta: CaseMeta,
        samples: &[f64],
        units: f64,
    ) {
        self.record_with_meta(name, Some(meta), samples, units);
    }

    fn record_with_meta(
        &mut self,
        name: &str,
        meta: Option<CaseMeta>,
        samples: &[f64],
        units: f64,
    ) {
        if !self.enabled(name) || samples.is_empty() {
            return;
        }
        let stats = Stats::from_samples(samples);
        let thr = if units > 0.0 { Some(units / stats.mean) } else { None };
        println!("{}", render_line(name, &stats, thr));
        self.results.push(BenchResult {
            name: name.to_string(),
            stats,
            thr,
            meta,
        });
    }

    pub fn summary(&self) -> String {
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&render_line(&r.name, &r.stats, r.thr));
            out.push('\n');
        }
        out
    }

    /// Persist every metadata-carrying case to a JSON scoreboard, merged
    /// with the file's existing cases by name (other bench binaries append
    /// to the same file without clobbering each other). Format:
    ///
    /// ```json
    /// {"version":1,"cases":[{"name":..,"op":..,"shape":..,"threads":..,
    ///   "ns_per_iter":..,"p50_ns":..,"p95_ns":..,"thr_per_s":..}, ...]}
    /// ```
    /// [`Bench::write_json`] at the shared scoreboard location:
    /// `$FAL_BENCH_JSON`, defaulting to `BENCH_native.json` in the current
    /// directory. Every bench binary writes here so the cases merge into
    /// one file. Returns the resolved path.
    pub fn write_json_default(&self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::PathBuf::from(
            std::env::var("FAL_BENCH_JSON")
                .unwrap_or_else(|_| "BENCH_native.json".to_string()),
        );
        self.write_json(&path)?;
        Ok(path)
    }

    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let mut by_name = std::collections::BTreeMap::new();
        if let Ok(old) = std::fs::read_to_string(path) {
            if let Ok(v) = Json::parse(&old) {
                if let Some(Json::Arr(cases)) = v.opt("cases") {
                    for c in cases {
                        if let Ok(n) =
                            c.get("name").and_then(|n| n.as_str().map(String::from))
                        {
                            by_name.insert(n, c.clone());
                        }
                    }
                }
            }
        }
        for r in &self.results {
            let Some(meta) = &r.meta else { continue };
            let mut pairs = vec![
                ("name", json::s(&r.name)),
                ("op", json::s(&meta.op)),
                ("shape", json::s(&meta.shape)),
                ("threads", json::num(meta.threads as f64)),
                ("ns_per_iter", json::num((r.stats.mean * 1e9).round())),
                ("p50_ns", json::num((r.stats.p50 * 1e9).round())),
                ("p95_ns", json::num((r.stats.p95 * 1e9).round())),
            ];
            if let Some(t) = r.thr {
                pairs.push(("thr_per_s", json::num(t.round())));
            }
            by_name.insert(r.name.clone(), json::obj(pairs));
        }
        let doc = json::obj(vec![
            ("version", json::num(1.0)),
            ("cases", Json::Arr(by_name.into_values().collect())),
        ]);
        std::fs::write(path, doc.dump() + "\n")
    }
}

fn render_line(name: &str, s: &Stats, thr: Option<f64>) -> String {
    let base = format!(
        "{name:<52} mean {:>10}  p50 {:>10}  p95 {:>10}",
        humanize(s.mean),
        humanize(s.p50),
        humanize(s.p95)
    );
    match thr {
        Some(t) => format!("{base}  thr {t:>12.1}/s"),
        None => base,
    }
}

/// Human-readable duration.
pub fn humanize(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn humanize_ranges() {
        assert!(humanize(5e-9).ends_with("ns"));
        assert!(humanize(5e-6).ends_with("µs"));
        assert!(humanize(5e-3).ends_with("ms"));
        assert!(humanize(5.0).ends_with('s'));
    }

    #[test]
    fn bench_collects() {
        let mut b = Bench::with_iters(3, 1);
        let mut n = 0u64;
        b.bench("count", 100.0, || {
            n += 1;
            n
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].thr.unwrap() > 0.0);
        // warmup(1) + iters(3)
        assert_eq!(n, 4);
    }

    #[test]
    fn record_external() {
        let mut b = Bench::with_iters(1, 0);
        b.record("ext", &[0.1, 0.2, 0.3], 0.0);
        assert_eq!(b.results[0].stats.n, 3);
        assert!(b.results[0].meta.is_none());
        // record_case carries metadata -> persisted by write_json.
        b.record_case(
            "frac",
            CaseMeta::new("overlap_fraction", "tiny", 4),
            &[0.5],
            0.0,
        );
        assert!(b.results[1].meta.is_some());
        assert!((b.results[1].stats.mean - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_scoreboard_merges_by_name() {
        let dir = std::env::temp_dir().join("fal_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_native.json");
        let _ = std::fs::remove_file(&path);

        let mut b1 = Bench::with_iters(2, 0);
        b1.bench_case("matmul_t1", CaseMeta::new("matmul", "8x8x8", 1), 512.0, || 1);
        b1.bench_case("matmul_t4", CaseMeta::new("matmul", "8x8x8", 4), 512.0, || 1);
        b1.bench("untagged", 0.0, || 1); // no meta -> not persisted
        b1.write_json(&path).unwrap();

        // A second binary writes one overlapping + one new case.
        let mut b2 = Bench::with_iters(2, 0);
        b2.bench_case("matmul_t1", CaseMeta::new("matmul", "8x8x8", 1), 512.0, || 1);
        b2.bench_case("tp_step", CaseMeta::new("tp_train_step", "tiny", 2), 1.0, || 1);
        b2.write_json(&path).unwrap();

        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        let names: Vec<&str> = cases
            .iter()
            .map(|c| c.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["matmul_t1", "matmul_t4", "tp_step"]);
        for c in cases {
            assert!(c.get("ns_per_iter").unwrap().as_f64().unwrap() >= 0.0);
            assert!(c.get("threads").unwrap().as_usize().unwrap() >= 1);
        }
        let _ = std::fs::remove_file(&path);
    }
}
