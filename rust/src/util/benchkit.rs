//! Bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets declare `harness = false` and drive this runner:
//! warmup, timed iterations, mean/p50/p95 and optional throughput, with a
//! `--filter` CLI matching criterion's substring selection.

use std::time::Instant;

use super::timer::Stats;

pub struct Bench {
    filter: Option<String>,
    pub results: Vec<(String, Stats, Option<f64>)>,
    warmup_iters: usize,
    iters: usize,
}

impl Bench {
    pub fn from_env() -> Bench {
        // `cargo bench -- --filter foo --iters 20`
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut filter = None;
        let mut iters = 10;
        let mut warmup = 2;
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--filter" if i + 1 < argv.len() => {
                    filter = Some(argv[i + 1].clone());
                    i += 1;
                }
                "--iters" if i + 1 < argv.len() => {
                    iters = argv[i + 1].parse().unwrap_or(10);
                    i += 1;
                }
                "--warmup" if i + 1 < argv.len() => {
                    warmup = argv[i + 1].parse().unwrap_or(2);
                    i += 1;
                }
                // `cargo bench` passes --bench; ignore unknown args.
                _ => {}
            }
            i += 1;
        }
        Bench { filter, results: vec![], warmup_iters: warmup, iters }
    }

    pub fn with_iters(iters: usize, warmup: usize) -> Bench {
        Bench { filter: None, results: vec![], warmup_iters: warmup, iters }
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter
            .as_ref()
            .map(|f| name.contains(f.as_str()))
            .unwrap_or(true)
    }

    /// Time `f` (called once per iteration). `units_per_iter`, if nonzero,
    /// reports throughput (units/s) — tokens, bytes, elements.
    pub fn bench<T>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let stats = Stats::from_samples(&samples);
        let thr = if units_per_iter > 0.0 {
            Some(units_per_iter / stats.mean)
        } else {
            None
        };
        println!("{}", render_line(name, &stats, thr));
        self.results.push((name.to_string(), stats, thr));
    }

    /// Record an externally-measured sample set (e.g. per-step times from a
    /// training loop) under this bench's reporting format.
    pub fn record(&mut self, name: &str, samples: &[f64], units: f64) {
        if !self.enabled(name) || samples.is_empty() {
            return;
        }
        let stats = Stats::from_samples(samples);
        let thr = if units > 0.0 { Some(units / stats.mean) } else { None };
        println!("{}", render_line(name, &stats, thr));
        self.results.push((name.to_string(), stats, thr));
    }

    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (name, stats, thr) in &self.results {
            out.push_str(&render_line(name, stats, *thr));
            out.push('\n');
        }
        out
    }
}

fn render_line(name: &str, s: &Stats, thr: Option<f64>) -> String {
    let base = format!(
        "{name:<52} mean {:>10}  p50 {:>10}  p95 {:>10}",
        humanize(s.mean),
        humanize(s.p50),
        humanize(s.p95)
    );
    match thr {
        Some(t) => format!("{base}  thr {t:>12.1}/s"),
        None => base,
    }
}

/// Human-readable duration.
pub fn humanize(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn humanize_ranges() {
        assert!(humanize(5e-9).ends_with("ns"));
        assert!(humanize(5e-6).ends_with("µs"));
        assert!(humanize(5e-3).ends_with("ms"));
        assert!(humanize(5.0).ends_with('s'));
    }

    #[test]
    fn bench_collects() {
        let mut b = Bench::with_iters(3, 1);
        let mut n = 0u64;
        b.bench("count", 100.0, || {
            n += 1;
            n
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].2.unwrap() > 0.0);
        // warmup(1) + iters(3)
        assert_eq!(n, 4);
    }

    #[test]
    fn record_external() {
        let mut b = Bench::with_iters(1, 0);
        b.record("ext", &[0.1, 0.2, 0.3], 0.0);
        assert_eq!(b.results[0].1.n, 3);
    }
}
