//! Lightweight timing utilities: scoped stopwatches and named accumulators.
//!
//! The TP trainer uses [`Breakdown`] to attribute wall-clock to the paper's
//! Fig 7 categories (FWD / BWD / Comm / (De)Comp / Opt).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Named duration accumulators for phase breakdowns.
#[derive(Debug, Default, Clone)]
pub struct Breakdown {
    acc: BTreeMap<String, f64>,
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        *self.acc.entry(name.to_string()).or_default() += secs;
    }

    /// Time a closure into the named bucket.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn get(&self, name: &str) -> f64 {
        self.acc.get(name).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.acc.values().sum()
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, f64)> {
        self.acc.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Percentage share per bucket.
    pub fn shares(&self) -> Vec<(String, f64)> {
        let total = self.total().max(1e-12);
        self.acc
            .iter()
            .map(|(k, v)| (k.clone(), 100.0 * v / total))
            .collect()
    }

    pub fn merge(&mut self, other: &Breakdown) {
        for (k, v) in &other.acc {
            *self.acc.entry(k.clone()).or_default() += v;
        }
    }
}

/// Summary statistics over repeated measurements.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| s[((s.len() - 1) as f64 * p).round() as usize];
        Stats {
            n: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            min: s[0],
            max: s[s.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = Breakdown::new();
        b.add("fwd", 1.0);
        b.add("fwd", 0.5);
        b.add("comm", 0.5);
        assert_eq!(b.get("fwd"), 1.5);
        assert_eq!(b.total(), 2.0);
        let shares = b.shares();
        assert_eq!(shares[1].0, "fwd");
        assert!((shares[1].1 - 75.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_times_closures() {
        let mut b = Breakdown::new();
        let v = b.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(b.get("work") >= 0.004);
    }

    #[test]
    fn merge_sums() {
        let mut a = Breakdown::new();
        a.add("x", 1.0);
        let mut b = Breakdown::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.n, 5);
        assert!((s.mean - 22.0).abs() < 1e-9);
    }
}
