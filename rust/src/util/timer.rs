//! Lightweight timing utilities: scoped stopwatches and named accumulators.
//!
//! The TP trainer uses [`Breakdown`] to attribute wall-clock to the paper's
//! Fig 7 categories (FWD / BWD / Comm / (De)Comp / Opt). Since the
//! StageGraph scheduler runs stages on concurrent worker lanes, the
//! accumulator is interior-mutable (`&self` recording, Mutex-guarded) and
//! offers two recording modes:
//!
//! * [`Breakdown::add`] / [`Breakdown::time`] — plain duration sums, for
//!   sequential phases.
//! * [`Breakdown::span`] — a drop-guard recording a `(start, end)` wall
//!   interval. Overlapping spans of the same bucket are merged by interval
//!   union, so a phase whose stages overlap across workers reports
//!   **wall-clock**, not the sum of per-worker times.
//!
//! [`Breakdown::get`] returns `sum + union(spans)` per bucket. A bucket's
//! intervals collapse into a scalar whenever its last open guard drops, so
//! span memory is bounded by concurrent guards, not run length.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

#[derive(Debug, Default, Clone)]
struct Inner {
    /// Plain summed durations per bucket.
    acc: BTreeMap<String, f64>,
    /// Union length of already-collapsed span history per bucket.
    closed: BTreeMap<String, f64>,
    /// Wall intervals per bucket not yet collapsible, seconds relative to
    /// `epoch`.
    spans: BTreeMap<String, Vec<(f64, f64)>>,
    /// Start times of currently-open guards per bucket.
    open: BTreeMap<String, Vec<f64>>,
    /// Buckets whose intervals are kept verbatim (never collapsed) so
    /// cross-bucket overlap can be measured after the fact. Opt-in
    /// ([`Breakdown::retain_intervals`]) because memory then grows with
    /// the number of spans, not the number of concurrent guards.
    retained: std::collections::BTreeSet<String>,
}

/// Named duration accumulators for phase breakdowns (thread-safe; see the
/// module docs for the two recording modes).
#[derive(Debug)]
pub struct Breakdown {
    /// Common clock origin for span intervals.
    epoch: Instant,
    inner: Mutex<Inner>,
}

impl Default for Breakdown {
    fn default() -> Self {
        Breakdown { epoch: Instant::now(), inner: Mutex::new(Inner::default()) }
    }
}

impl Clone for Breakdown {
    fn clone(&self) -> Self {
        Breakdown {
            epoch: self.epoch,
            inner: Mutex::new(self.inner.lock().unwrap().clone()),
        }
    }
}

/// The single definition of a bucket's total: plain sums + collapsed span
/// history + the union of still-pending spans. `get`, `entries` (and
/// therefore `total`/`shares`) all read through here.
fn bucket_total(inner: &Inner, name: &str) -> f64 {
    inner.acc.get(name).copied().unwrap_or(0.0)
        + inner.closed.get(name).copied().unwrap_or(0.0)
        + inner.spans.get(name).map(|s| union_secs(s)).unwrap_or(0.0)
}

/// Sorted, merged union of (possibly overlapping) intervals.
fn merge_intervals(spans: &[(f64, f64)]) -> Vec<(f64, f64)> {
    if spans.is_empty() {
        return vec![];
    }
    let mut sorted = spans.to_vec();
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut out: Vec<(f64, f64)> = vec![sorted[0]];
    for &(s, e) in &sorted[1..] {
        let last = out.last_mut().unwrap();
        if s <= last.1 {
            last.1 = last.1.max(e);
        } else {
            out.push((s, e));
        }
    }
    out
}

/// Total length of the union of (possibly overlapping) intervals.
fn union_secs(spans: &[(f64, f64)]) -> f64 {
    merge_intervals(spans).iter().map(|(s, e)| e - s).sum()
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `secs` into the named bucket (plain sum).
    pub fn add(&self, name: &str, secs: f64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.acc.entry(name.to_string()).or_default() += secs;
    }

    /// Time a closure into the named bucket (plain sum).
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Open a wall-clock span in the named bucket; the interval is
    /// recorded when the guard drops. Safe to call from concurrent worker
    /// tasks — overlapping intervals of one bucket union-merge, so the
    /// bucket reports wall time, not summed worker time. Whenever a
    /// bucket's last open guard drops, its accumulated intervals collapse
    /// into a scalar (a later guard's interval starts at "now", after
    /// every collapsed end, so the union is exact) — memory stays bounded
    /// by the number of concurrently-open guards, not by run length.
    pub fn span(&self, name: &str) -> SpanGuard<'_> {
        let mut inner = self.inner.lock().unwrap();
        // Clock read *under* the lock: any collapse that completed before
        // this guard existed acquired the lock first, so its collapsed
        // ends all precede this start — the exactness invariant.
        let start = self.epoch.elapsed().as_secs_f64();
        inner.open.entry(name.to_string()).or_default().push(start);
        SpanGuard { bd: self, name: name.to_string(), start }
    }

    /// Close a guard's interval: deregister the open start, record the
    /// interval, and collapse the bucket once no guards remain open.
    fn close_span(&self, name: &str, start: f64, end: f64) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        if let Some(starts) = inner.open.get_mut(name) {
            if let Some(i) = starts.iter().position(|&s| s == start) {
                starts.swap_remove(i);
            }
        }
        inner
            .spans
            .entry(name.to_string())
            .or_default()
            .push((start, end));
        let quiescent =
            inner.open.get(name).map(|v| v.is_empty()).unwrap_or(true);
        if quiescent && !inner.retained.contains(name) {
            if let Some(spans) = inner.spans.get_mut(name) {
                let settled = union_secs(spans);
                spans.clear();
                *inner.closed.entry(name.to_string()).or_default() += settled;
            }
        }
    }

    /// Keep the named bucket's span intervals verbatim instead of
    /// collapsing them on quiescence, so [`Breakdown::intervals`] and
    /// [`Breakdown::intersection_secs`] can inspect them later (the
    /// realized comm/compute overlap measurement). Memory for that bucket
    /// then grows with recorded spans — bench/test opt-in.
    pub fn retain_intervals(&self, name: &str) {
        self.inner
            .lock()
            .unwrap()
            .retained
            .insert(name.to_string());
    }

    /// Closed wall intervals of a (retained) bucket, epoch-relative.
    pub fn intervals(&self, name: &str) -> Vec<(f64, f64)> {
        self.inner
            .lock()
            .unwrap()
            .spans
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Wall-clock length of `union(a) ∩ union(b)` — how much of bucket
    /// `a`'s wall time was concurrently covered by bucket `b`. Both
    /// buckets must have been retained ([`Breakdown::retain_intervals`]);
    /// non-retained (collapsed) history is invisible here.
    pub fn intersection_secs(&self, a: &str, b: &str) -> f64 {
        let ua = merge_intervals(&self.intervals(a));
        let ub = merge_intervals(&self.intervals(b));
        let (mut i, mut j, mut total) = (0usize, 0usize, 0.0f64);
        while i < ua.len() && j < ub.len() {
            let lo = ua[i].0.max(ub[j].0);
            let hi = ua[i].1.min(ub[j].1);
            if hi > lo {
                total += hi - lo;
            }
            if ua[i].1 <= ub[j].1 {
                i += 1;
            } else {
                j += 1;
            }
        }
        total
    }

    /// Raw interval insert (no open-guard bookkeeping, no collapsing) —
    /// kept for tests that construct synthetic overlap patterns.
    #[cfg(test)]
    fn record_span(&self, name: &str, start: f64, end: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner
            .spans
            .entry(name.to_string())
            .or_default()
            .push((start, end));
    }

    /// Bucket total: plain sums + collapsed span history + the union of
    /// still-pending spans.
    pub fn get(&self, name: &str) -> f64 {
        bucket_total(&self.inner.lock().unwrap(), name)
    }

    /// All buckets with their totals, name-sorted.
    pub fn entries(&self) -> Vec<(String, f64)> {
        let inner = self.inner.lock().unwrap();
        let mut names: Vec<&String> = inner
            .acc
            .keys()
            .chain(inner.closed.keys())
            .chain(inner.spans.keys())
            .collect();
        names.sort();
        names.dedup();
        names
            .into_iter()
            .map(|n| (n.clone(), bucket_total(&inner, n)))
            .collect()
    }

    pub fn total(&self) -> f64 {
        self.entries().iter().map(|(_, v)| v).sum()
    }

    /// Percentage share per bucket.
    pub fn shares(&self) -> Vec<(String, f64)> {
        let entries = self.entries();
        let total: f64 = entries.iter().map(|(_, v)| v).sum::<f64>().max(1e-12);
        entries
            .into_iter()
            .map(|(k, v)| (k, 100.0 * v / total))
            .collect()
    }

    /// Fold `other`'s bucket totals into this accumulator's plain sums
    /// (spans collapse to their union — the clocks don't share an epoch).
    pub fn merge(&self, other: &Breakdown) {
        for (k, v) in other.entries() {
            self.add(&k, v);
        }
    }
}

/// Drop guard for [`Breakdown::span`].
pub struct SpanGuard<'b> {
    bd: &'b Breakdown,
    name: String,
    start: f64,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let end = self.bd.epoch.elapsed().as_secs_f64();
        self.bd.close_span(&self.name, self.start, end);
    }
}

/// Summary statistics over repeated measurements.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| s[((s.len() - 1) as f64 * p).round() as usize];
        Stats {
            n: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            min: s[0],
            max: s[s.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let b = Breakdown::new();
        b.add("fwd", 1.0);
        b.add("fwd", 0.5);
        b.add("comm", 0.5);
        assert_eq!(b.get("fwd"), 1.5);
        assert_eq!(b.total(), 2.0);
        let shares = b.shares();
        assert_eq!(shares[1].0, "fwd");
        assert!((shares[1].1 - 75.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_times_closures() {
        let b = Breakdown::new();
        let v = b.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(b.get("work") >= 0.004);
    }

    #[test]
    fn merge_sums() {
        let a = Breakdown::new();
        a.add("x", 1.0);
        let b = Breakdown::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }

    #[test]
    fn concurrent_adds_do_not_race() {
        // The scheduler records from worker tasks: &self adds from many
        // threads must all land.
        let b = Breakdown::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        b.add("bwd", 0.001);
                    }
                });
            }
        });
        assert!((b.get("bwd") - 8.0).abs() < 1e-9);
    }

    #[test]
    fn union_merges_overlaps() {
        assert_eq!(union_secs(&[]), 0.0);
        assert_eq!(union_secs(&[(0.0, 1.0)]), 1.0);
        // Full overlap, partial overlap, disjoint.
        assert!((union_secs(&[(0.0, 1.0), (0.0, 1.0)]) - 1.0).abs() < 1e-12);
        assert!(
            (union_secs(&[(0.0, 1.0), (0.5, 2.0), (3.0, 4.0)]) - 3.0).abs()
                < 1e-12
        );
        // Unsorted input.
        assert!(
            (union_secs(&[(3.0, 4.0), (0.0, 2.0), (1.0, 2.5)]) - 3.5).abs()
                < 1e-12
        );
    }

    #[test]
    fn overlapping_spans_report_wall_clock() {
        // Four overlapped intervals recorded from concurrent workers:
        // the bucket reports their 1s union, not the 3.4s sum.
        let b = Breakdown::new();
        std::thread::scope(|s| {
            for i in 0..4 {
                let b = &b;
                s.spawn(move || b.record_span("fwd", i as f64 * 0.1, 1.0));
            }
        });
        assert!((b.get("fwd") - 1.0).abs() < 1e-9, "{}", b.get("fwd"));
    }

    #[test]
    fn sequential_spans_sum() {
        let b = Breakdown::new();
        for _ in 0..2 {
            let _g = b.span("opt");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(b.get("opt") >= 0.008);
        // Each guard closed with no overlap pending, so the intervals
        // collapsed into the scalar history — span memory stays bounded.
        {
            let inner = b.inner.lock().unwrap();
            assert!(inner
                .spans
                .get("opt")
                .map(|v| v.is_empty())
                .unwrap_or(true));
            assert!(inner.closed.get("opt").copied().unwrap_or(0.0) >= 0.008);
        }
        // Spans and adds combine in one bucket.
        b.add("opt", 1.0);
        assert!(b.get("opt") >= 1.008);
        assert_eq!(b.entries().len(), 1);
    }

    #[test]
    fn concurrent_guards_collapse_to_wall_clock() {
        // Real guards overlapping across threads: the union survives the
        // collapse-on-quiescence path (the last drop folds everything).
        let b = Breakdown::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let _g = b.span("fwd");
                    std::thread::sleep(Duration::from_millis(5));
                });
            }
        });
        let t = b.get("fwd");
        assert!(t >= 0.004, "union lost time: {t}");
        // All guards dropped -> pending spans collapsed.
        assert!(b
            .inner
            .lock()
            .unwrap()
            .spans
            .get("fwd")
            .map(|v| v.is_empty())
            .unwrap_or(true));
    }

    #[test]
    fn retained_intervals_survive_and_intersect() {
        let b = Breakdown::new();
        b.retain_intervals("comm");
        b.retain_intervals("compute");
        // Synthetic pattern: comm [0,2] and [5,6]; compute [1,4].
        b.record_span("comm", 0.0, 2.0);
        b.record_span("comm", 5.0, 6.0);
        b.record_span("compute", 1.0, 4.0);
        assert_eq!(b.intervals("comm").len(), 2);
        // Totals still read through the union.
        assert!((b.get("comm") - 3.0).abs() < 1e-12);
        // comm ∩ compute = [1,2] -> 1s.
        assert!((b.intersection_secs("comm", "compute") - 1.0).abs() < 1e-12);
        assert!((b.intersection_secs("compute", "comm") - 1.0).abs() < 1e-12);
        // Disjoint / missing buckets intersect to zero.
        assert_eq!(b.intersection_secs("comm", "nope"), 0.0);
    }

    #[test]
    fn retained_guards_do_not_collapse() {
        let b = Breakdown::new();
        b.retain_intervals("opt");
        for _ in 0..3 {
            let _g = b.span("opt");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(b.intervals("opt").len(), 3);
        assert!(b.get("opt") >= 0.004);
        // A non-retained bucket still collapses (bounded memory).
        for _ in 0..2 {
            let _g = b.span("fwd");
        }
        assert!(b.intervals("fwd").is_empty());
    }

    #[test]
    fn merge_intervals_merges() {
        assert!(merge_intervals(&[]).is_empty());
        assert_eq!(
            merge_intervals(&[(3.0, 4.0), (0.0, 2.0), (1.0, 2.5)]),
            vec![(0.0, 2.5), (3.0, 4.0)]
        );
    }

    #[test]
    fn clone_snapshots_state() {
        let b = Breakdown::new();
        b.add("x", 2.0);
        let c = b.clone();
        b.add("x", 1.0);
        assert_eq!(c.get("x"), 2.0);
        assert_eq!(b.get("x"), 3.0);
    }

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.n, 5);
        assert!((s.mean - 22.0).abs() < 1e-9);
    }
}
