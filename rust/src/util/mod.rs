//! Zero-dependency substrate utilities.
//!
//! The offline crate cache ships only the `xla` closure, so the framework
//! carries its own JSON parser, PRNG, CLI parser, table formatter, bench
//! harness and mini property-testing engine. Each is unit-tested in place.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod timer;
