//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw argv entries (without the program name).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args {
            positional: vec![],
            options: BTreeMap::new(),
            flags: vec![],
        };
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("--{rest} needs a value"))?;
                    out.options.insert(rest.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn expect_subcommand(&self, choices: &[&str]) -> Result<&str> {
        match self.positional.first() {
            Some(c) if choices.contains(&c.as_str()) => Ok(c),
            Some(c) => bail!("unknown subcommand {c:?}; one of {choices:?}"),
            None => bail!("missing subcommand; one of {choices:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            argv("train --steps 100 --lr=0.001 --verbose extra"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), &[]).unwrap();
        assert_eq!(a.usize_or("steps", 42).unwrap(), 42);
        assert_eq!(a.str_or("name", "x"), "x");
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("--steps"), &[]).is_err());
    }

    #[test]
    fn bad_int_errors() {
        let a = Args::parse(argv("--steps abc"), &[]).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(
            vec!["--variants".to_string(), "preln,fal, falplus".to_string()],
            &[],
        )
        .unwrap();
        assert_eq!(a.list_or("variants", &[]), vec!["preln", "fal", "falplus"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }

    #[test]
    fn subcommands() {
        let a = Args::parse(argv("exp fig6"), &[]).unwrap();
        assert_eq!(a.expect_subcommand(&["exp", "train"]).unwrap(), "exp");
        assert!(a.expect_subcommand(&["other"]).is_err());
    }
}
