//! Mini property-testing engine (proptest is unavailable offline).
//!
//! Seeded case generation with greedy shrinking: on failure, the engine
//! retries with each input vector element halved/zeroed/truncated until the
//! failure no longer reproduces, and reports the minimal failing case. Used
//! by the coordinator invariants (routing, batching, collectives, state) and
//! the compression codecs.

use super::rng::Rng;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink: usize,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: 0xFA1_5EED, max_shrink: 200 }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Check `prop` over `cases` random inputs drawn by `gen`.
    /// Panics with the (shrunk) counterexample on failure.
    pub fn check<T, G, P>(&self, name: &str, mut gen: G, mut prop: P)
    where
        T: Clone + std::fmt::Debug + Shrink,
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> bool,
    {
        let mut rng = Rng::new(self.seed);
        for case in 0..self.cases {
            let input = gen(&mut rng.split(case as u64));
            if !prop(&input) {
                let minimal = self.shrink(input, &mut prop);
                panic!(
                    "property {name:?} falsified (case {case}):\n{minimal:#?}"
                );
            }
        }
    }

    fn shrink<T, P>(&self, failing: T, prop: &mut P) -> T
    where
        T: Clone + std::fmt::Debug + Shrink,
        P: FnMut(&T) -> bool,
    {
        let mut current = failing;
        let mut budget = self.max_shrink;
        loop {
            let mut advanced = false;
            for cand in current.shrink_candidates() {
                if budget == 0 {
                    return current;
                }
                budget -= 1;
                if !prop(&cand) {
                    current = cand;
                    advanced = true;
                    break;
                }
            }
            if !advanced {
                return current;
            }
        }
    }
}

/// Types that know how to propose smaller versions of themselves.
pub trait Shrink: Sized {
    fn shrink_candidates(&self) -> Vec<Self>;
}

impl Shrink for Vec<f32> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = vec![];
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
        }
        // Zero elements one at a time (first nonzero).
        if let Some(i) = self.iter().position(|&x| x != 0.0) {
            let mut z = self.clone();
            z[i] = 0.0;
            out.push(z);
            let mut h = self.clone();
            h[i] /= 2.0;
            if h[i].abs() > 1e-30 {
                out.push(h);
            }
        }
        out
    }
}

impl Shrink for Vec<usize> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = vec![];
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
        }
        if let Some(i) = self.iter().position(|&x| x > 0) {
            let mut h = self.clone();
            h[i] /= 2;
            out.push(h);
        }
        out
    }
}

impl Shrink for usize {
    fn shrink_candidates(&self) -> Vec<Self> {
        match self {
            0 => vec![],
            n => vec![n / 2, n - 1],
        }
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(
            self.1
                .shrink_candidates()
                .into_iter()
                .map(|b| (self.0.clone(), b)),
        );
        out
    }
}

/// Generator helpers.
pub fn vec_f32(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
    let len = 1 + rng.below(max_len.max(1));
    (0..len).map(|_| rng.normal() as f32 * scale).collect()
}

pub fn vec_usize(rng: &mut Rng, max_len: usize, max_val: usize) -> Vec<usize> {
    let len = 1 + rng.below(max_len.max(1));
    (0..len).map(|_| rng.below(max_val.max(1))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        Prop::new(50).check(
            "sum-of-squares nonneg",
            |r| vec_f32(r, 20, 2.0),
            |v| v.iter().map(|x| x * x).sum::<f32>() >= 0.0,
        );
    }

    #[test]
    fn shrinks_to_small_case() {
        let caught = std::panic::catch_unwind(|| {
            Prop::new(100).check(
                "no element above 1",
                |r| vec_f32(r, 50, 1.0),
                |v| v.iter().all(|&x| x < 1.0),
            );
        });
        let err = caught.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        // Shrinker should reduce to a very short vector.
        let elements = msg.matches(',').count();
        assert!(elements <= 3, "shrunk case still large: {msg}");
    }

    #[test]
    fn tuple_shrinks_both_sides() {
        let cands = (vec![1.0f32, 2.0], vec![3usize, 4]).shrink_candidates();
        assert!(cands.iter().any(|(a, _)| a.len() == 1));
        assert!(cands.iter().any(|(_, b)| b.len() == 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(vec_f32(&mut r1, 10, 1.0), vec_f32(&mut r2, 10, 1.0));
    }
}
