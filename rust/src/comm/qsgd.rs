//! QSGD: communication-efficient SGD via stochastic gradient quantization
//! (Alistarh et al., NeurIPS 2017) — the paper's "Grad-Q" baseline.
//!
//! Gradients are split into buckets; each bucket is scaled by its max-abs
//! and every element is stochastically rounded to one of `levels` uniform
//! levels in [-1, 1]. Rounding is *unbiased*: E[decode(encode(g))] = g,
//! the property the original paper's convergence proof needs (and which we
//! property-test below). Wire format: one f32 scale per bucket + one i8
//! level per element (for levels <= 127).

use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::{Compressor, Payload};

pub struct Qsgd {
    /// Number of positive quantization levels (e.g. 4 -> 2-bit-ish + sign).
    pub levels: i8,
    pub bucket: usize,
    rng: Rng,
}

impl Qsgd {
    pub fn new(levels: i8, bucket: usize, seed: u64) -> Qsgd {
        assert!(levels >= 1);
        Qsgd { levels, bucket, rng: Rng::new(seed) }
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn compress(&mut self, grad: &HostTensor) -> (Payload, usize) {
        let n = grad.len();
        let nb = n.div_ceil(self.bucket);
        let mut scales = Vec::with_capacity(nb);
        let mut levels = Vec::with_capacity(n);
        for b in 0..nb {
            let lo = b * self.bucket;
            let hi = (lo + self.bucket).min(n);
            let chunk = &grad.data[lo..hi];
            let scale = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            scales.push(scale);
            if scale == 0.0 {
                levels.extend(std::iter::repeat(0i8).take(hi - lo));
                continue;
            }
            for &v in chunk {
                // |v|/scale * L = k + frac; round up with prob frac.
                let t = (v.abs() / scale) * self.levels as f32;
                let k = t.floor();
                let frac = t - k;
                let q = k as i8 + if self.rng.bool(frac as f64) { 1 } else { 0 };
                levels.push(if v < 0.0 { -q } else { q });
            }
        }
        // Wire size: scales (4B each) + one signed byte per element. (The
        // original packs levels tighter; 1B/elem is the standard simple
        // encoding and already gives ~4x.)
        let wire = scales.len() * 4 + levels.len();
        (
            Payload::Quantized { scales, levels, bucket: self.bucket },
            wire,
        )
    }

    fn decompress(&self, payload: &Payload, shape: &[usize]) -> HostTensor {
        let Payload::Quantized { scales, levels, bucket } = payload else {
            unreachable!("qsgd got foreign payload")
        };
        let mut out = HostTensor::zeros(shape);
        for (i, &lv) in levels.iter().enumerate() {
            let scale = scales[i / bucket];
            out.data[i] = lv as f32 / self.levels as f32 * scale;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{vec_f32, Prop};

    #[test]
    fn zero_grad_exact() {
        let g = HostTensor::zeros(&[64]);
        let mut c = Qsgd::new(4, 32, 0);
        let (p, _) = c.compress(&g);
        assert_eq!(c.decompress(&p, &[64]), g);
    }

    #[test]
    fn wire_size_is_quarter_ish() {
        let g = HostTensor::ones(&[1024]);
        let mut c = Qsgd::new(4, 256, 0);
        let (_, wire) = c.compress(&g);
        assert_eq!(wire, 4 * 4 + 1024);
        assert!(c.ratio(1024, wire) > 3.9);
    }

    #[test]
    fn unbiased_in_expectation() {
        // Average of many stochastic encodings converges to the input.
        let g = HostTensor::from_vec(&[4], vec![0.3, -0.7, 0.05, 1.0]);
        let mut acc = HostTensor::zeros(&[4]);
        let reps = 3000;
        for seed in 0..reps {
            let mut c = Qsgd::new(4, 4, seed);
            let (p, _) = c.compress(&g);
            acc.add_assign(&c.decompress(&p, &[4]));
        }
        acc.scale(1.0 / reps as f32);
        for (a, b) in acc.data.iter().zip(&g.data) {
            assert!((a - b).abs() < 0.02, "E[q]={a} vs {b}");
        }
    }

    #[test]
    fn bounded_error_property() {
        // |decode - x| <= scale/levels for every element (quantization cell).
        Prop::new(40).check(
            "qsgd bounded error",
            |r| vec_f32(r, 200, 2.0),
            |v| {
                let g = HostTensor::from_vec(&[v.len()], v.clone());
                let mut c = Qsgd::new(8, 64, 1234);
                let (p, _) = c.compress(&g);
                let d = c.decompress(&p, &[v.len()]);
                let Payload::Quantized { scales, bucket, .. } = &p else {
                    return false;
                };
                g.data.iter().enumerate().all(|(i, &x)| {
                    let cell = scales[i / bucket] / 8.0;
                    (d.data[i] - x).abs() <= cell + 1e-6
                })
            },
        );
    }

    #[test]
    fn sign_preserved() {
        Prop::new(40).check(
            "qsgd sign-or-zero",
            |r| vec_f32(r, 100, 1.0),
            |v| {
                let g = HostTensor::from_vec(&[v.len()], v.clone());
                let mut c = Qsgd::new(4, 32, 7);
                let (p, _) = c.compress(&g);
                let d = c.decompress(&p, &[v.len()]);
                d.data
                    .iter()
                    .zip(&g.data)
                    .all(|(&q, &x)| q == 0.0 || q.signum() == x.signum())
            },
        );
    }
}
