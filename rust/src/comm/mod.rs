//! Lossy communication-reduction baselines the paper compares against
//! (Sec 6.2, Fig 7): QSGD stochastic quantization [36] and PowerSGD
//! low-rank approximation [37], both with error feedback.
//!
//! These are real codecs operating on gradient tensors: `compress` returns
//! an encoded payload with an exact wire-size in bytes (what would cross
//! the interconnect), `decompress` reconstructs the (lossy) gradient. The
//! Fig 7 harness charges (de)compression wall-clock to the "(De)Comp"
//! bucket and wire bytes to the "Comm" bucket.

pub mod error_feedback;
pub mod powersgd;
pub mod qsgd;

use crate::tensor::HostTensor;

/// A gradient codec: anything that can stand in for the all-reduce payload.
pub trait Compressor {
    fn name(&self) -> &'static str;

    /// Encode; returns (payload, wire_bytes).
    fn compress(&mut self, grad: &HostTensor) -> (Payload, usize);

    /// Decode back to a dense gradient of the original shape.
    fn decompress(&self, payload: &Payload, shape: &[usize]) -> HostTensor;

    /// Compression ratio vs raw f32 for a tensor of n elements.
    fn ratio(&self, numel: usize, wire_bytes: usize) -> f64 {
        (numel * 4) as f64 / wire_bytes as f64
    }
}

/// Boxed codecs are codecs too, so callers can pick one at runtime
/// (`fal tp --compress qsgd|powersgd`) and still use [`Compressor`]-generic
/// wrappers like `ErrorFeedback`. `Send + Sync` because the trainer holding
/// the box is shared across scoped worker threads.
impl Compressor for Box<dyn Compressor + Send + Sync> {
    fn name(&self) -> &'static str {
        self.as_ref().name()
    }

    fn compress(&mut self, grad: &HostTensor) -> (Payload, usize) {
        self.as_mut().compress(grad)
    }

    fn decompress(&self, payload: &Payload, shape: &[usize]) -> HostTensor {
        self.as_ref().decompress(payload, shape)
    }
}

/// Encoded gradient payloads.
#[derive(Debug, Clone)]
pub enum Payload {
    /// QSGD: per-bucket scale + packed signed levels.
    Quantized { scales: Vec<f32>, levels: Vec<i8>, bucket: usize },
    /// PowerSGD: left/right factors (rank-r).
    LowRank { p: HostTensor, q: HostTensor, rows: usize, cols: usize },
    /// Identity (no compression) — baseline path.
    Dense(HostTensor),
}

/// No-op codec (the GPT-2 baseline path in Fig 7).
pub struct DenseCodec;

impl Compressor for DenseCodec {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn compress(&mut self, grad: &HostTensor) -> (Payload, usize) {
        (Payload::Dense(grad.clone()), grad.size_bytes())
    }

    fn decompress(&self, payload: &Payload, _shape: &[usize]) -> HostTensor {
        match payload {
            Payload::Dense(t) => t.clone(),
            _ => unreachable!("dense codec got foreign payload"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_exact() {
        let g = HostTensor::from_vec(&[2, 2], vec![1.0, -2.0, 3.0, 4.0]);
        let mut c = DenseCodec;
        let (p, bytes) = c.compress(&g);
        assert_eq!(bytes, 16);
        assert_eq!(c.decompress(&p, &[2, 2]), g);
        assert!((c.ratio(4, bytes) - 1.0).abs() < 1e-9);
    }
}
