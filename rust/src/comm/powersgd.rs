//! PowerSGD: practical low-rank gradient compression (Vogels et al.,
//! NeurIPS 2019) — the paper's "Grad-LR" baseline.
//!
//! A matrix-shaped gradient M [n, m] is approximated as P Q^T with rank r:
//! one subspace (power) iteration per step, warm-started from the previous
//! Q. Wire cost is (n + m) * r * 4 bytes instead of n * m * 4. Vectors
//! (1-D tensors) are sent dense, as in the original. Orthogonalization is
//! Gram-Schmidt, matching the reference implementation.

use std::collections::BTreeMap;

use crate::tensor::HostTensor;
use crate::util::rng::Rng;

use super::{Compressor, Payload};

pub struct PowerSgd {
    pub rank: usize,
    /// Warm-start Q per tensor shape-key.
    q_memory: BTreeMap<(usize, usize), HostTensor>,
    rng: Rng,
}

impl PowerSgd {
    pub fn new(rank: usize, seed: u64) -> PowerSgd {
        PowerSgd { rank, q_memory: BTreeMap::new(), rng: Rng::new(seed) }
    }

    fn as_matrix(shape: &[usize]) -> Option<(usize, usize)> {
        if shape.len() < 2 {
            return None;
        }
        let rows = shape[0];
        let cols: usize = shape[1..].iter().product();
        Some((rows, cols))
    }
}

/// out[n,r] = a[n,m] @ b[m,r]
fn matmul(a: &[f32], n: usize, m: usize, b: &[f32], r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * r];
    for i in 0..n {
        for k in 0..m {
            let av = a[i * m + k];
            if av == 0.0 {
                continue;
            }
            let brow = &b[k * r..k * r + r];
            let orow = &mut out[i * r..i * r + r];
            for j in 0..r {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// out[m,r] = a^T[m,n] @ b[n,r] where a is [n,m]
fn matmul_t(a: &[f32], n: usize, m: usize, b: &[f32], r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * r];
    for i in 0..n {
        let arow = &a[i * m..i * m + m];
        let brow = &b[i * r..i * r + r];
        for k in 0..m {
            let av = arow[k];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[k * r..k * r + r];
            for j in 0..r {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// In-place modified Gram-Schmidt on the r columns of x [n, r].
///
/// Projections are subtracted twice ("twice is enough", Parlett/Kahan): a
/// single pass leaves a residual of *correlated* f32 rounding noise that is
/// still nearly parallel to the earlier columns, which normalization then
/// amplifies into a spurious direction. Columns whose residual collapses
/// relative to their original norm (input rank < r) are zeroed.
fn orthogonalize(x: &mut [f32], n: usize, r: usize) {
    for j in 0..r {
        let mut orig = 0.0f64;
        for i in 0..n {
            orig += (x[i * r + j] as f64).powi(2);
        }
        let orig = orig.sqrt();
        for _pass in 0..2 {
            for k in 0..j {
                let mut dot = 0.0f64;
                for i in 0..n {
                    dot += x[i * r + j] as f64 * x[i * r + k] as f64;
                }
                for i in 0..n {
                    x[i * r + j] -= dot as f32 * x[i * r + k];
                }
            }
        }
        let mut norm = 0.0f64;
        for i in 0..n {
            norm += (x[i * r + j] as f64).powi(2);
        }
        let norm = norm.sqrt();
        if norm < 1e-5 * orig.max(1e-20) || norm == 0.0 {
            // Degenerate column (input rank < r): zero it rather than
            // normalize numerical noise into a garbage direction.
            for i in 0..n {
                x[i * r + j] = 0.0;
            }
        } else {
            let inv = (1.0 / norm) as f32;
            for i in 0..n {
                x[i * r + j] *= inv;
            }
        }
    }
}

impl Compressor for PowerSgd {
    fn name(&self) -> &'static str {
        "powersgd"
    }

    fn compress(&mut self, grad: &HostTensor) -> (Payload, usize) {
        let Some((n, m)) = Self::as_matrix(&grad.shape) else {
            return (Payload::Dense(grad.clone()), grad.size_bytes());
        };
        let r = self.rank.min(n).min(m);
        // Warm-started Q [m, r].
        let q = self
            .q_memory
            .entry((n, m))
            .or_insert_with(|| {
                let mut t = HostTensor::zeros(&[m, r]);
                self.rng.fill_normal(&mut t.data, 1.0);
                orthogonalize(&mut t.data, m, r);
                t
            })
            .clone();
        // P = M Q ; orthogonalize P ; Q' = M^T P.
        let mut p = matmul(&grad.data, n, m, &q.data, r);
        orthogonalize(&mut p, n, r);
        let q_new = matmul_t(&grad.data, n, m, &p, r);
        let p_t = HostTensor::from_vec(&[n, r], p);
        let q_t = HostTensor::from_vec(&[m, r], q_new);
        self.q_memory.insert((n, m), q_t.clone());
        let wire = (n + m) * r * 4;
        (Payload::LowRank { p: p_t, q: q_t, rows: n, cols: m }, wire)
    }

    fn decompress(&self, payload: &Payload, shape: &[usize]) -> HostTensor {
        match payload {
            Payload::Dense(t) => t.clone(),
            Payload::LowRank { p, q, rows, cols } => {
                let r = p.shape[1];
                // M' = P Q^T
                let mut out = HostTensor::zeros(shape);
                for i in 0..*rows {
                    for j in 0..*cols {
                        let mut acc = 0.0f32;
                        for k in 0..r {
                            acc += p.data[i * r + k] * q.data[j * r + k];
                        }
                        out.data[i * cols + j] = acc;
                    }
                }
                out
            }
            _ => unreachable!("powersgd got foreign payload"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rank1_matrix(n: usize, m: usize) -> HostTensor {
        // outer(u, v): exactly rank 1.
        let mut t = HostTensor::zeros(&[n, m]);
        for i in 0..n {
            for j in 0..m {
                t.data[i * m + j] = (i + 1) as f32 * 0.1 * (j as f32 - 2.0);
            }
        }
        t
    }

    #[test]
    fn rank1_reconstructed_exactly() {
        let g = rank1_matrix(8, 6);
        let mut c = PowerSgd::new(2, 0);
        // Two iterations to let the power iteration converge.
        let (_, _) = c.compress(&g);
        let (p, wire) = c.compress(&g);
        let d = c.decompress(&p, &[8, 6]);
        assert!(d.rel_err(&g) < 1e-3, "rel err {}", d.rel_err(&g));
        assert_eq!(wire, (8 + 6) * 2 * 4);
    }

    #[test]
    fn vectors_pass_dense() {
        let g = HostTensor::from_vec(&[5], vec![1., 2., 3., 4., 5.]);
        let mut c = PowerSgd::new(2, 0);
        let (p, wire) = c.compress(&g);
        assert_eq!(wire, 20);
        assert_eq!(c.decompress(&p, &[5]), g);
    }

    #[test]
    fn compression_ratio_large() {
        let g = HostTensor::ones(&[256, 256]);
        let mut c = PowerSgd::new(4, 0);
        let (_, wire) = c.compress(&g);
        assert!(c.ratio(256 * 256, wire) > 30.0);
    }

    #[test]
    fn warm_start_improves() {
        // Random full-rank matrix: error after 3 warm-started steps must be
        // <= error after 1 (power iteration converges to top-r subspace).
        let mut rng = Rng::new(3);
        let mut g = HostTensor::zeros(&[32, 16]);
        rng.fill_normal(&mut g.data, 1.0);
        let mut c = PowerSgd::new(4, 1);
        let (p1, _) = c.compress(&g);
        let e1 = c.decompress(&p1, &[32, 16]).rel_err(&g);
        let (_, _) = c.compress(&g);
        let (p3, _) = c.compress(&g);
        let e3 = c.decompress(&p3, &[32, 16]).rel_err(&g);
        assert!(e3 <= e1 + 1e-6, "e1={e1} e3={e3}");
    }

    #[test]
    fn orthogonalize_produces_orthonormal_columns() {
        let mut rng = Rng::new(4);
        let (n, r) = (20, 3);
        let mut x = vec![0.0f32; n * r];
        rng.fill_normal(&mut x, 1.0);
        orthogonalize(&mut x, n, r);
        for a in 0..r {
            for b in 0..r {
                let dot: f64 = (0..n)
                    .map(|i| x[i * r + a] as f64 * x[i * r + b] as f64)
                    .sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "col {a}.{b}: {dot}");
            }
        }
    }

    #[test]
    fn higher_rank_lower_error() {
        let mut rng = Rng::new(5);
        let mut g = HostTensor::zeros(&[24, 24]);
        rng.fill_normal(&mut g.data, 1.0);
        let err = |rank| {
            let mut c = PowerSgd::new(rank, 2);
            for _ in 0..3 {
                c.compress(&g);
            }
            let (p, _) = c.compress(&g);
            c.decompress(&p, &[24, 24]).rel_err(&g)
        };
        assert!(err(8) < err(2));
    }
}
