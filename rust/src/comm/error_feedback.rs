//! Error feedback (EF-SGD) wrapper around a lossy codec.
//!
//! The residual of each compression step is carried into the next one:
//!   send_t = C(g_t + e_{t-1});  e_t = (g_t + e_{t-1}) - decode(send_t)
//! Both QSGD and PowerSGD are deployed with EF in practice (PowerSGD
//! requires it); Fig 7's "Grad-Q"/"Grad-LR" runs use this wrapper.

use std::collections::BTreeMap;

use crate::tensor::HostTensor;

use super::{Compressor, Payload};

pub struct ErrorFeedback<C: Compressor> {
    pub inner: C,
    residual: BTreeMap<String, HostTensor>,
}

impl<C: Compressor> ErrorFeedback<C> {
    pub fn new(inner: C) -> Self {
        ErrorFeedback { inner, residual: BTreeMap::new() }
    }

    /// Compress `grad` for the tensor identified by `key`, applying and
    /// updating the residual. Returns (reconstructed gradient, wire_bytes):
    /// the reconstruction is what every worker applies after the (simulated)
    /// all-reduce of compressed payloads.
    pub fn transmit(&mut self, key: &str, grad: &HostTensor) -> (HostTensor, usize) {
        let mut carried = grad.clone();
        if let Some(e) = self.residual.get(key) {
            carried.add_assign(e);
        }
        let (payload, wire) = self.inner.compress(&carried);
        let decoded = self.inner.decompress(&payload, &grad.shape);
        let mut resid = carried;
        resid.axpy(-1.0, &decoded);
        self.residual.insert(key.to_string(), resid);
        (decoded, wire)
    }

    /// Total residual norm (diagnostic: must stay bounded during training).
    pub fn residual_norm(&self) -> f64 {
        self.residual.values().map(|t| t.sq_norm()).sum::<f64>().sqrt()
    }
}

/// Convenience: dense passthrough keyed API so the Fig 7 harness can treat
/// all three baselines uniformly.
pub fn transmit_dense(grad: &HostTensor) -> (HostTensor, usize) {
    (grad.clone(), grad.size_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::qsgd::Qsgd;
    use crate::util::rng::Rng;

    #[test]
    fn residual_corrects_bias_over_time() {
        // With a *constant* gradient, sum of EF-transmitted reconstructions
        // over T steps must approach T * g (the defining EF property).
        let g = HostTensor::from_vec(&[8], vec![0.11; 8]);
        let mut ef = ErrorFeedback::new(Qsgd::new(2, 8, 3));
        let mut acc = HostTensor::zeros(&[8]);
        let t = 50;
        for _ in 0..t {
            let (d, _) = ef.transmit("w", &g);
            acc.add_assign(&d);
        }
        for &v in &acc.data {
            assert!(
                (v - 0.11 * t as f32).abs() < 0.15,
                "accumulated {v} vs {}",
                0.11 * t as f32
            );
        }
    }

    #[test]
    fn residual_stays_bounded() {
        let mut rng = Rng::new(9);
        let mut ef = ErrorFeedback::new(Qsgd::new(4, 64, 5));
        for _ in 0..100 {
            let g = HostTensor::randn(&[128], 1.0, &mut rng);
            ef.transmit("w", &g);
        }
        // Residual per element stays within a few quantization cells.
        assert!(ef.residual_norm() < 10.0, "{}", ef.residual_norm());
    }

    #[test]
    fn independent_keys_independent_residuals() {
        let mut ef = ErrorFeedback::new(Qsgd::new(2, 4, 1));
        let g1 = HostTensor::from_vec(&[4], vec![0.3; 4]);
        ef.transmit("a", &g1);
        assert_eq!(ef.residual.len(), 1);
        ef.transmit("b", &g1);
        assert_eq!(ef.residual.len(), 2);
    }
}
