//! Experiment reporting: collect tables/series and emit Markdown + CSV.
//!
//! Every experiment module returns a [`Report`]; the CLI appends them to
//! `reports/` and the EXPERIMENTS.md workflow copies the rendered Markdown.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::table::{series_line, Table};

#[derive(Debug, Default)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub notes: Vec<String>,
    pub tables: Vec<Table>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    pub fn table(&mut self, t: Table) -> &mut Self {
        self.tables.push(t);
        self
    }

    pub fn series(&mut self, name: &str, xs: Vec<f64>) -> &mut Self {
        self.series.push((name.to_string(), xs));
        self
    }

    pub fn render_markdown(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for n in &self.notes {
            let _ = writeln!(out, "- {n}");
        }
        if !self.notes.is_empty() {
            out.push('\n');
        }
        for t in &self.tables {
            out.push_str(&t.render_markdown());
            out.push('\n');
        }
        for (name, xs) in &self.series {
            let _ = writeln!(out, "```\n{}\n```", series_line(name, xs));
        }
        out
    }

    pub fn render_text(&self) -> String {
        let mut out = format!("===== {} — {} =====\n", self.id, self.title);
        for n in &self.notes {
            let _ = writeln!(out, "  * {n}");
        }
        for t in &self.tables {
            out.push_str(&t.render_text());
            out.push('\n');
        }
        for (name, xs) in &self.series {
            let _ = writeln!(out, "{}", series_line(name, xs));
        }
        out
    }

    /// Persist markdown + raw CSV of every table under `dir/<id>.*`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {dir:?}"))?;
        std::fs::write(dir.join(format!("{}.md", self.id)),
                       self.render_markdown())?;
        let mut csv = String::new();
        for t in &self.tables {
            let _ = writeln!(csv, "# {}", t.title);
            let _ = writeln!(csv, "{}", t.headers.join(","));
            for row in &t.rows {
                let _ = writeln!(csv, "{}", row.join(","));
            }
        }
        for (name, xs) in &self.series {
            let _ = writeln!(csv, "# series {name}");
            let _ = writeln!(
                csv,
                "{}",
                xs.iter()
                    .map(|x| format!("{x}"))
                    .collect::<Vec<_>>()
                    .join(",")
            );
        }
        std::fs::write(dir.join(format!("{}.csv", self.id)), csv)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_sections() {
        let mut r = Report::new("fig0", "Demo");
        r.note("a note");
        let mut t = Table::new("T", &["x"]);
        t.row(vec!["1".into()]);
        r.table(t);
        r.series("loss", vec![3.0, 2.0, 1.0]);
        let md = r.render_markdown();
        assert!(md.contains("## fig0 — Demo"));
        assert!(md.contains("- a note"));
        assert!(md.contains("| x |"));
        assert!(md.contains("loss:"));
        assert!(r.render_text().contains("====="));
    }

    #[test]
    fn saves_files() {
        let dir = std::env::temp_dir().join("fal_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Report::new("figX", "T");
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        r.table(t);
        r.save(&dir).unwrap();
        assert!(dir.join("figX.md").exists());
        let csv = std::fs::read_to_string(dir.join("figX.csv")).unwrap();
        assert!(csv.contains("a,b"));
        assert!(csv.contains("1,2"));
    }
}
