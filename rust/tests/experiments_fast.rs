//! Fast experiment-registry integration: the cost-model figures must
//! regenerate with paper-consistent shapes without any training.

use std::path::Path;

use fal::experiments::{self, ExpCtx};

fn ctx() -> ExpCtx {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    ExpCtx::new(&dir, 0.1).expect("run `make artifacts` first")
}

#[test]
fn fig6_fal_always_at_most_baseline() {
    let report = experiments::run(&ctx(), "fig6").unwrap();
    // Every normalized-time cell must be < 1 (FAL never slower).
    let t = &report.tables[0];
    for row in &t.rows {
        for cell in &row[2..] {
            let v: f64 = cell.parse().unwrap();
            assert!(v < 1.0, "cell {cell} not a speedup in {row:?}");
            assert!(v > 0.4, "cell {cell} implausibly fast");
        }
    }
}

#[test]
fn fig8_ratios_in_paper_band() {
    let report = experiments::run(&ctx(), "fig8").unwrap();
    let t = &report.tables[0];
    for row in &t.rows {
        let flash: f64 = row[2].parse().unwrap();
        assert!((1.0..1.25).contains(&flash), "{row:?}");
    }
    // Fig 8(b): every counter must not decrease under overlap.
    let t8b = &report.tables[1];
    for row in &t8b.rows {
        assert!(row[3].starts_with('+'), "{row:?}");
    }
}

#[test]
fn fig19_savings_grow_with_gpus() {
    let report = experiments::run(&ctx(), "fig19").unwrap();
    let t = &report.tables[0];
    // For each (model, seq) group of 4 rows (1,2,4,8 GPUs), saving at 8
    // GPUs must exceed saving at 1 GPU.
    for grp in t.rows.chunks(4) {
        let pct = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        assert!(
            pct(&grp[3][5]) >= pct(&grp[0][5]),
            "saving should grow with GPUs: {grp:?}"
        );
    }
}

#[test]
fn fig10_tp_fastest() {
    let report = experiments::run(&ctx(), "fig10").unwrap();
    let t = &report.tables[0];
    let time = |i: usize| t.rows[i][1].parse::<f64>().unwrap();
    let (dp, pp, tp, fal) = (time(0), time(1), time(2), time(3));
    assert!(tp < dp && tp < pp, "TP must be fastest: {dp} {pp} {tp}");
    assert!(fal < tp, "FAL must beat plain TP");
}

#[test]
fn unknown_experiment_rejected() {
    assert!(experiments::run(&ctx(), "fig99").is_err());
}
