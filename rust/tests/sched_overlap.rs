//! Property tests for the overlap scheduler: random DAGs with interleaved
//! CommNodes must produce 0-ulp identical results under serial, graph and
//! overlap execution, no node may run before its declared dependencies
//! completed (value-wise), and the simulated comm drain must actually
//! overlap compute in overlap mode — on the toy DAGs here and on the real
//! TP trainer / GPipe pipeline.

use std::sync::atomic::{AtomicBool, Ordering};

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::dp_pp::PpTrainer;
use fal::coordinator::sp_trainer::{Schedule, Trainer};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::data::{Batch, Corpus, CorpusSpec, Loader};
use fal::runtime::sched::{COMM_BUCKET, COMPUTE_BUCKET};
use fal::runtime::{Backend, ExecCtx, NativeBackend, SchedMode, StageGraph};
use fal::util::proptest::{Prop, Shrink};
use fal::util::rng::Rng;

const MODES: [SchedMode; 3] =
    [SchedMode::Serial, SchedMode::Graph, SchedMode::Overlap];

// ---------------------------------------------------------------------------
// Random-DAG machinery
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct DagNode {
    deps: Vec<usize>,
    comm: bool,
}

#[derive(Debug, Clone)]
struct DagSpec {
    nodes: Vec<DagNode>,
}

impl Shrink for DagSpec {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = vec![];
        // Prefix truncation keeps every dep id valid (deps < id).
        if self.nodes.len() > 1 {
            out.push(DagSpec {
                nodes: self.nodes[..self.nodes.len() / 2].to_vec(),
            });
        }
        if let Some(i) = self.nodes.iter().position(|n| n.comm) {
            let mut c = self.clone();
            c.nodes[i].comm = false;
            out.push(c);
        }
        if let Some(i) = self.nodes.iter().position(|n| !n.deps.is_empty()) {
            let mut c = self.clone();
            c.nodes[i].deps.pop();
            out.push(c);
        }
        out
    }
}

fn gen_dag(rng: &mut Rng) -> DagSpec {
    let n = 1 + rng.below(12);
    let mut nodes = Vec::with_capacity(n);
    for i in 0..n {
        let mut deps = vec![];
        if i > 0 {
            for _ in 0..rng.below(4) {
                deps.push(rng.below(i));
            }
            deps.sort_unstable();
            deps.dedup();
        }
        nodes.push(DagNode { deps, comm: rng.below(3) == 0 });
    }
    DagSpec { nodes }
}

/// Execute the DAG: node values are f64s mixed from the node id and its
/// dependency values (deterministic given structure, order-sensitive in
/// the bits); every closure asserts its deps completed before it started.
/// Returns the value bits in node-id order.
fn run_dag(spec: &DagSpec, threads: usize, mode: SchedMode) -> Vec<u64> {
    let n = spec.nodes.len();
    let done: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let done = &done;
    let mut g: StageGraph<'_, f64> = StageGraph::new();
    for (i, node) in spec.nodes.iter().enumerate() {
        let deps = node.deps.clone();
        let f = move |_: &ExecCtx, j: &fal::runtime::Joined<'_, f64>| {
            for &d in &deps {
                assert!(
                    done[d].load(Ordering::SeqCst),
                    "node {i} ran before dep {d} completed"
                );
            }
            let mut v = ((i + 2) as f64).sqrt();
            for &d in &deps {
                v = v * 1.0000001 + *j.get(d);
            }
            done[i].store(true, Ordering::SeqCst);
            v
        };
        if node.comm {
            // Small but real drain, so overlap-mode eagerness is exercised.
            g.comm_node(format!("c{i}"), &node.deps, 0.0003, f);
        } else {
            g.node(format!("n{i}"), &node.deps, f);
        }
    }
    let ctx = ExecCtx::new(threads).with_sched(mode);
    g.run(&ctx).into_iter().map(f64::to_bits).collect()
}

#[test]
fn random_dags_zero_ulp_across_modes_and_no_early_nodes() {
    Prop::new(40).check(
        "random comm DAGs: overlap == graph == serial, deps honored",
        gen_dag,
        |spec: &DagSpec| {
            let base = run_dag(spec, 1, SchedMode::Serial);
            for threads in [2usize, 4, 7] {
                for mode in MODES {
                    if run_dag(spec, threads, mode) != base {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn wide_comm_fan_does_not_deadlock_overlap() {
    // Many independent comm nodes + one sink: more drains than lanes.
    let mut g: StageGraph<'_, u64> = StageGraph::new();
    let ids: Vec<usize> = (0..9)
        .map(|i| g.comm_node(format!("c{i}"), &[], 0.001, move |_, _| i as u64))
        .collect();
    let deps = ids.clone();
    g.node("sink", &ids, move |_, j| deps.iter().map(|&d| *j.get(d)).sum());
    let out = g.run(&ExecCtx::new(3).with_sched(SchedMode::Overlap));
    assert_eq!(out[9], 36);
}

// ---------------------------------------------------------------------------
// Real-trainer overlap acceptance
// ---------------------------------------------------------------------------

fn batch(engine: &NativeBackend, seed: u64) -> Batch {
    let cfg = engine.manifest().config("tiny").unwrap();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 20_000, 3);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, seed);
    loader.fixed_batch(seed)
}

/// Acceptance: under `--sched overlap` with a simulated link, the comm
/// span union sits (partly) inside compute spans — the in-flight
/// reduction is measurably hidden behind the next block's stage nodes.
#[test]
fn tp_simulated_comm_overlaps_next_block_compute() {
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        return; // one core cannot overlap anything
    }
    let eng = NativeBackend::synthetic_with_ctx(
        ExecCtx::new(4).with_sched(SchedMode::Overlap),
    );
    let b = batch(&eng, 21);
    let mut tp = TpTrainer::new(
        &eng, "tiny", Variant::Fal, 2, PCIE_GEN4, TrainConfig::default(),
    )
    .unwrap();
    // ~2ms of virtual link per all-reduce (tiny/PCIe4 rings are ~33us).
    tp.comm_sim_scale = 60.0;
    tp.breakdown.retain_intervals(COMM_BUCKET);
    tp.breakdown.retain_intervals(COMPUTE_BUCKET);
    tp.train_step(&b).unwrap();
    let comm = tp.breakdown.get(COMM_BUCKET);
    let compute = tp.breakdown.get(COMPUTE_BUCKET);
    let hidden = tp.breakdown.intersection_secs(COMM_BUCKET, COMPUTE_BUCKET);
    assert!(comm > 0.0, "no comm wall-clock recorded");
    assert!(compute > 0.0, "no compute wall-clock recorded");
    assert!(
        hidden > 0.0,
        "no comm/compute overlap realized (comm {comm:.4}s, compute \
         {compute:.4}s)"
    );
}

/// The comm simulation must not perturb values: a simulated-link run is
/// 0-ulp identical to the unsimulated one in every mode.
#[test]
fn comm_simulation_does_not_change_tp_results() {
    let run = |sim: f64, mode: SchedMode| {
        let eng = NativeBackend::synthetic_with_ctx(
            ExecCtx::new(2).with_sched(mode),
        );
        let b = batch(&eng, 22);
        let mut tp = TpTrainer::new(
            &eng, "tiny", Variant::Fal, 2, PCIE_GEN4, TrainConfig::default(),
        )
        .unwrap();
        tp.comm_sim_scale = sim;
        let (loss, _) = tp.train_step(&b).unwrap();
        loss.to_bits()
    };
    let base = run(0.0, SchedMode::Serial);
    for mode in MODES {
        assert_eq!(run(10.0, mode), base, "{mode:?} with sim diverged");
    }
}

/// GPipe pipeline: losses are 0-ulp identical across the three schedules
/// (and thread counts), agree with the monolithic forward up to micro-batch
/// reduction rounding, and the byte accounting is schedule-invariant.
#[test]
fn pipeline_three_way_zero_ulp_and_matches_monolithic() {
    let run = |threads: usize, mode: SchedMode, micro: usize| {
        let eng = NativeBackend::synthetic_with_ctx(
            ExecCtx::new(threads).with_sched(mode),
        );
        let b = batch(&eng, 23);
        let mut pp = PpTrainer::new(&eng, "tiny", 2, micro, PCIE_GEN4).unwrap();
        pp.comm_sim_scale = 5.0;
        let loss = pp.forward_loss(&b).unwrap();
        (loss, pp.ledger.stats())
    };
    for micro in [2usize, 4] {
        let (base, base_stats) = run(1, SchedMode::Serial, micro);
        for threads in [1usize, 2, 4, 7] {
            for mode in MODES {
                let (loss, stats) = run(threads, mode, micro);
                assert_eq!(
                    loss.to_bits(),
                    base.to_bits(),
                    "pp m{micro} {mode:?} t{threads} loss diverged"
                );
                assert_eq!(stats.broadcasts, base_stats.broadcasts);
                assert_eq!(stats.broadcast_bytes, base_stats.broadcast_bytes);
            }
        }
        // (stages-1) x micro boundary sends per forward.
        assert_eq!(base_stats.broadcasts, micro as u64);
    }

    // Against the monolithic fused forward (sp trainer eval at lr 0).
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 23);
    let mut pp = PpTrainer::new(&eng, "tiny", 2, 2, PCIE_GEN4).unwrap();
    let pp_loss = pp.forward_loss(&b).unwrap();
    let mut sp = Trainer::new(&eng, "tiny", "preln", Schedule::Constant).unwrap();
    let sp_loss = sp.eval_loss(&b).unwrap();
    let rel = ((pp_loss - sp_loss) / sp_loss).abs();
    assert!(
        rel < 1e-3,
        "pipeline {pp_loss} vs monolithic {sp_loss} (rel {rel})"
    );
}

/// Pipeline sends drain while the upstream device computes the next
/// micro-batch: measurable overlap one level above TP.
#[test]
fn pipeline_sends_overlap_next_micro_batch() {
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        return;
    }
    let eng = NativeBackend::synthetic_with_ctx(
        ExecCtx::new(4).with_sched(SchedMode::Overlap),
    );
    let b = batch(&eng, 24);
    let mut pp = PpTrainer::new(&eng, "tiny", 2, 4, PCIE_GEN4).unwrap();
    // broadcast_time(65536/4 B, PCIe4) ~ 13us; scale to ~1.3ms per send.
    pp.comm_sim_scale = 100.0;
    pp.breakdown.retain_intervals(COMM_BUCKET);
    pp.breakdown.retain_intervals(COMPUTE_BUCKET);
    pp.forward_loss(&b).unwrap();
    let hidden = pp.breakdown.intersection_secs(COMM_BUCKET, COMPUTE_BUCKET);
    assert!(
        hidden > 0.0,
        "no send/compute overlap (comm {:.5}s, compute {:.5}s)",
        pp.breakdown.get(COMM_BUCKET),
        pp.breakdown.get(COMPUTE_BUCKET)
    );
}
