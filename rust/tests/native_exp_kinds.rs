//! Integration tests for the native model-level artifact kinds that back
//! `fal exp all` on the default build: grad_step (finite-difference
//! checked), gradmag, eval_masked (gate semantics + consistency with the
//! fused train step), score_options (ranking invariance), capture (stage
//! composition), and the non-preln/fal train-step variants.

use std::path::Path;

use fal::coordinator::sp_trainer::{Schedule, Trainer};
use fal::coordinator::topology::NamedParams;
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::experiments::{self, ExpCtx};
use fal::runtime::{Backend, Manifest, NativeBackend};
use fal::tensor::HostTensor;
use fal::util::rng::Rng;

fn backend() -> NativeBackend {
    NativeBackend::synthetic()
}

/// Random (tokens, targets) pair for a config.
fn token_pair(
    eng: &NativeBackend,
    config: &str,
    batch: usize,
    seed: u64,
) -> (HostTensor, HostTensor) {
    let cfg = eng.manifest().config(config).unwrap().clone();
    let mut rng = Rng::new(seed);
    let toks: Vec<i32> = (0..batch * cfg.seq_len)
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mut shifted = toks.clone();
    shifted.rotate_left(1);
    (
        HostTensor::from_i32(&[batch, cfg.seq_len], &toks),
        HostTensor::from_i32(&[batch, cfg.seq_len], &shifted),
    )
}

#[test]
fn grad_step_finite_difference() {
    let eng = backend();
    for tag in ["preln", "fal"] {
        let spec = eng.manifest().find("grad_step", "micro", tag).unwrap();
        let name = spec.name.clone();
        let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
        let params = eng.load_params("micro", 0).unwrap();
        let np = params.len();
        let (tok, tgt) = token_pair(&eng, "micro", batch, 3);
        let run = |p: &[HostTensor]| -> Vec<HostTensor> {
            let mut inputs = p.to_vec();
            inputs.push(tok.clone());
            inputs.push(tgt.clone());
            eng.execute(&name, &inputs).unwrap()
        };
        let out = run(&params);
        assert_eq!(out.len(), 1 + np);
        let loss = out[0].data[0];
        assert!(loss.is_finite());

        // Central differences on a few parameters across tensor kinds.
        let schema = eng.manifest().schema("micro").unwrap();
        let idx_of = |n: &str| {
            schema.iter().position(|p| p.name == n).unwrap()
        };
        let h = 3e-3f32;
        for (pname, elem) in [
            ("wte", 5usize),
            ("blocks.0.w1", 3),
            ("blocks.1.wo", 2),
            ("blocks.0.ln1_g", 1),
        ] {
            let pi = idx_of(pname);
            let mut pp = params.clone();
            let mut pm = params.clone();
            pp[pi].data[elem] += h;
            pm[pi].data[elem] -= h;
            let num =
                (run(&pp)[0].data[0] - run(&pm)[0].data[0]) / (2.0 * h);
            let ana = out[1 + pi].data[elem];
            assert!(
                (num - ana).abs() < 2e-2 * ana.abs().max(1.0),
                "{tag} d{pname}[{elem}]: numeric {num} vs analytic {ana}"
            );
        }
    }
}

#[test]
fn gradmag_shapes_and_first_block_nonzero() {
    let eng = backend();
    let spec = eng.manifest().find("gradmag", "micro", "preln").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let cfg = eng.manifest().config("micro").unwrap().clone();
    let mut inputs = eng.load_params("micro", 0).unwrap();
    let (tok, tgt) = token_pair(&eng, "micro", batch, 4);
    inputs.push(tok);
    inputs.push(tgt);
    let out = eng.execute(&name, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![cfg.n_layer]);
    for (li, v) in out[0].data.iter().enumerate() {
        assert!(v.is_finite() && *v > 0.0, "block {li}: ||dA|| = {v}");
    }
}

#[test]
fn eval_masked_matches_trainer_eval_loss() {
    let eng = backend();
    let cfg = eng.manifest().config("tiny").unwrap().clone();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 20_000, 3);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, 7);
    let b = loader.fixed_batch(1);
    for tag in ["preln", "fal", "falplus", "parallel"] {
        let mut sp =
            Trainer::new(&eng, "tiny", tag, Schedule::Constant).unwrap();
        let sp_loss = sp.eval_loss(&b).unwrap() as f64;

        let spec = eng.manifest().find("eval_masked", "tiny", tag).unwrap();
        let mut inputs = eng.load_params("tiny", 0).unwrap();
        inputs.push(b.tokens.clone());
        inputs.push(b.targets.clone());
        inputs.push(HostTensor::ones(&[cfg.n_layer]));
        inputs.push(HostTensor::ones(&[cfg.n_layer]));
        let out = eng.execute(&spec.name.clone(), &inputs).unwrap();
        let masked = out[0].data[0] as f64 / out[1].data[0] as f64;
        let rel = ((masked - sp_loss) / sp_loss).abs();
        assert!(
            rel < 1e-4,
            "{tag}: eval_masked {masked} vs trainer eval {sp_loss} (rel {rel})"
        );
    }
}

#[test]
fn eval_masked_gates_change_loss() {
    let eng = backend();
    let cfg = eng.manifest().config("micro").unwrap().clone();
    let spec = eng.manifest().find("eval_masked", "micro", "preln").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let params = eng.load_params("micro", 17).unwrap();
    let (tok, tgt) = token_pair(&eng, "micro", batch, 5);
    let run = |mha: f32, conn: f32| -> f32 {
        let mut inputs = params.clone();
        inputs.push(tok.clone());
        inputs.push(tgt.clone());
        let mut m = HostTensor::ones(&[cfg.n_layer]);
        m.scale(mha);
        let mut c = HostTensor::ones(&[cfg.n_layer]);
        c.scale(conn);
        inputs.push(m);
        inputs.push(c);
        let out = eng.execute(&name, &inputs).unwrap();
        out[0].data[0] / out[1].data[0]
    };
    let original = run(1.0, 1.0);
    let no_mha = run(0.0, 0.0);
    let amplified = run(3.0, 3.0);
    assert!(original.is_finite() && no_mha.is_finite());
    assert_ne!(original, no_mha, "removing all MHA must change the loss");
    assert_ne!(original, amplified);
}

#[test]
fn score_options_invariant_to_padding_and_batch_position() {
    let eng = backend();
    let spec =
        eng.manifest().find("score_options", "micro", "preln").unwrap();
    let name = spec.name.clone();
    let params = eng.load_params("micro", 0).unwrap();
    // micro: batch 2, seq 5. Row A scores option token 3 after prompt
    // [1, 2]; the mask covers only position 1, whose logits depend on
    // tokens[0..=1] alone — so the padding tail must not matter.
    let mask_row = [0.0f32, 1.0, 0.0, 0.0, 0.0];
    let score = |rows: [[i32; 5]; 2], tgts: [[i32; 5]; 2]| -> Vec<f32> {
        let toks: Vec<i32> = rows.concat();
        let tg: Vec<i32> = tgts.concat();
        let mut inputs = params.clone();
        inputs.push(HostTensor::from_i32(&[2, 5], &toks));
        inputs.push(HostTensor::from_i32(&[2, 5], &tg));
        inputs.push(HostTensor::from_vec(
            &[2, 5],
            [mask_row, mask_row].concat(),
        ));
        eng.execute(&name, &inputs).unwrap()[0].data.clone()
    };
    let a = [1, 2, 3, 9, 9];
    let a_tgt = [2, 3, 9, 9, 4];
    // Same prompt/option, different padding tail.
    let s1 = score([a, [1, 2, 3, 7, 8]], [a_tgt, [2, 3, 7, 8, 5]]);
    assert!(
        (s1[0] - s1[1]).abs() < 1e-6,
        "padding tail changed the masked score: {} vs {}",
        s1[0],
        s1[1]
    );
    // Same row scored at a different batch position, next to a different
    // neighbor: batch elements are independent.
    let s2 = score([[4, 6, 2, 1, 0], a], [[6, 2, 1, 0, 7], a_tgt]);
    assert!(
        (s1[0] - s2[1]).abs() < 1e-6,
        "batch position changed the score: {} vs {}",
        s1[0],
        s2[1]
    );
    // And a genuinely different option scores differently.
    let s3 = score([a, [1, 2, 8, 9, 9]], [a_tgt, [2, 8, 9, 9, 4]]);
    assert!((s3[0] - s3[1]).abs() > 1e-7, "different options tied exactly");
}

#[test]
fn capture_matches_stage_composition() {
    let eng = backend();
    let spec = eng.manifest().find("capture", "micro", "preln").unwrap();
    let cap_name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let cfg = eng.manifest().config("micro").unwrap().clone();
    let schema = eng.manifest().schema("micro").unwrap().to_vec();
    let flat = eng.load_params("micro", 0).unwrap();
    let (tok, _) = token_pair(&eng, "micro", batch, 6);

    let mut inputs = flat.clone();
    inputs.push(tok.clone());
    let caps = eng.execute(&cap_name, &inputs).unwrap();
    assert_eq!(caps.len(), 3);
    let (b, s, d) = (batch, cfg.seq_len, cfg.d_model);
    for c in &caps {
        assert_eq!(c.shape, vec![cfg.n_layer, b, s, d]);
        assert!(c.data.iter().all(|v| v.is_finite()));
    }

    // Recompute block 0's MHA output from the TP stages at tp = 1 and
    // compare against the first layer of the captured stream.
    let named = NamedParams::from_flat(&schema, flat);
    let x = eng
        .execute(
            &Manifest::tp_stage_name("micro", 1, batch, "embed_fwd"),
            &[
                tok.clone(),
                named.get("wte").unwrap().clone(),
                named.get("wpe").unwrap().clone(),
            ],
        )
        .unwrap();
    let mut attn_in = vec![x[0].clone()];
    for f in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo"] {
        attn_in.push(named.blk(0, f).unwrap().clone());
    }
    let a0 = eng
        .execute(
            &Manifest::tp_stage_name("micro", 1, batch, "attn_fwd"),
            &attn_in,
        )
        .unwrap();
    let cap0 = HostTensor::from_vec(
        &[b, s, d],
        caps[0].data[..b * s * d].to_vec(),
    );
    let rel = cap0.rel_err(&a0[0]);
    assert!(rel < 1e-5, "capture mha_out[0] vs attn stage: rel {rel}");
}

#[test]
fn all_train_step_variants_learn() {
    // micro keeps the 7-variant sweep at CI speed; preln/fal at tiny
    // scale are already covered by tests/tp_equivalence.rs.
    let eng = backend();
    let cfg = eng.manifest().config("micro").unwrap().clone();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 5_000, 3);
    let loader = Loader::new(&corpus, cfg.seq_len, 2, 0.1, 11);
    let b = loader.fixed_batch(2);
    for tag in
        ["preln", "parallel", "fal", "falplus", "ablation1", "ablation2",
         "falplus_k2"]
    {
        let mut t = Trainer::new(&eng, "micro", tag, Schedule::Constant)
            .unwrap_or_else(|e| panic!("{tag}: {e:#}"));
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..12 {
            let out = t.train_step(&b).unwrap();
            assert!(out.loss.is_finite() && out.gnorm.is_finite(), "{tag}");
            if first.is_none() {
                first = Some(out.loss);
            }
            last = out.loss;
        }
        let first = first.unwrap();
        assert!(
            last < first - 0.01,
            "{tag}: loss did not fall on a fixed batch ({first} -> {last})"
        );
    }
}

#[test]
fn gqa_and_moe_train_steps_execute_and_update_their_params() {
    // micro_gqa / micro_moe share the artifact surface of the Fig 20
    // hosts (small_gqa / small_moe) at gradient-check cost.
    let eng = backend();
    for config in ["micro_gqa", "micro_moe"] {
        let spec = eng.manifest().find("train_step", config, "fal").unwrap();
        let name = spec.name.clone();
        let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
        let schema = eng.manifest().schema(config).unwrap().to_vec();
        let np = schema.len();
        let params = eng.load_params(config, 0).unwrap();
        let zeros: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        let (tok, tgt) = token_pair(&eng, config, batch, 9);
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * np + 4);
        inputs.extend(params.iter().cloned());
        inputs.extend(zeros.iter().cloned());
        inputs.extend(zeros.iter().cloned());
        inputs.push(HostTensor::scalar(1.0));
        inputs.push(HostTensor::scalar(1.0));
        inputs.push(tok);
        inputs.push(tgt);
        let out = eng.execute(&name, &inputs).unwrap();
        assert!(out[0].data[0].is_finite(), "{config}: loss");
        assert!(out[1].data[0] > 0.0, "{config}: gnorm");
        // First-moment outputs are (1 - beta1) * grad after step 1, so a
        // nonzero momentum proves the parameter actually received gradient
        // — for MoE that includes the router and expert projections (the
        // MoE backward is wired in), for GQA the narrowed wk/wv.
        let probes: &[&str] = if config == "micro_moe" {
            &["blocks.0.router", "blocks.0.wq_experts", "blocks.0.wq"]
        } else {
            &["blocks.0.wk", "blocks.0.wv", "blocks.0.wq"]
        };
        for pname in probes {
            let i = schema.iter().position(|p| p.name == *pname).unwrap();
            assert!(
                out[2 + np + i].norm() > 0.0,
                "{config}: {pname} received no gradient"
            );
        }
    }
}

/// The Fig 20 hosts carry the eval kinds too (ROADMAP item): eval_masked
/// with unit gates must agree with the fused-step eval loss on GQA and
/// MoE-attention configs, and score_options must produce per-sequence
/// log-likelihoods — the zero-shot suite's primitive on those hosts.
#[test]
fn eval_kinds_execute_on_gqa_and_moe_hosts() {
    let eng = backend();
    for config in ["micro_gqa", "micro_moe"] {
        let cfg = eng.manifest().config(config).unwrap().clone();
        let corpus =
            Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 5_000, 5);
        let loader = Loader::new(&corpus, cfg.seq_len, 2, 0.1, 7);
        let b = loader.fixed_batch(1);
        for tag in ["preln", "fal", "falplus"] {
            let mut sp =
                Trainer::new(&eng, config, tag, Schedule::Constant).unwrap();
            let sp_loss = sp.eval_loss(&b).unwrap() as f64;
            let spec = eng.manifest().find("eval_masked", config, tag).unwrap();
            let mut inputs = eng.load_params(config, 0).unwrap();
            inputs.push(b.tokens.clone());
            inputs.push(b.targets.clone());
            inputs.push(HostTensor::ones(&[cfg.n_layer]));
            inputs.push(HostTensor::ones(&[cfg.n_layer]));
            let out = eng.execute(&spec.name.clone(), &inputs).unwrap();
            let masked = out[0].data[0] as f64 / out[1].data[0] as f64;
            let rel = ((masked - sp_loss) / sp_loss).abs();
            assert!(
                rel < 1e-4,
                "{config}/{tag}: eval_masked {masked} vs trainer {sp_loss}"
            );
        }
        // score_options: one finite log-likelihood per batch row.
        let spec =
            eng.manifest().find("score_options", config, "fal").unwrap();
        let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
        let mut inputs = eng.load_params(config, 0).unwrap();
        let (tok, tgt) = token_pair(&eng, config, batch, 21);
        inputs.push(tok);
        inputs.push(tgt);
        inputs.push(HostTensor::ones(&[batch, cfg.seq_len]));
        let out = eng.execute(&spec.name.clone(), &inputs).unwrap();
        assert_eq!(out[0].shape, vec![batch], "{config}");
        assert!(
            out[0].data.iter().all(|v| v.is_finite() && *v < 0.0),
            "{config}: masked log-likelihoods must be finite and negative"
        );
    }
}

/// End-to-end: a whole experiment id that previously required the PJRT
/// backend (capture + gradmag + eval_masked + training) now runs natively.
#[test]
fn appendix_c_motivation_runs_natively() {
    let mut ctx =
        ExpCtx::new(Path::new("/nonexistent/artifacts"), 0.02).unwrap();
    ctx.out_dir = std::env::temp_dir();
    let report = experiments::run(&ctx, "appendix-c").unwrap();
    assert!(!report.tables.is_empty());
}
