//! Native-backend integration: the default-features counterpart of
//! runtime_roundtrip.rs. Exercises the synthetic manifest, the stage
//! dispatcher, shard-sum consistency (the TP invariant), finite-difference
//! gradient checks on the `micro` config, and the fused train step.

use fal::runtime::{Backend, Manifest, NativeBackend};
use fal::tensor::HostTensor;
use fal::util::rng::Rng;

fn backend() -> NativeBackend {
    NativeBackend::synthetic()
}

/// Random stage inputs matching the artifact spec (LN gains set to 1).
fn stage_inputs(b: &NativeBackend, name: &str, seed: u64) -> Vec<HostTensor> {
    let spec = b.manifest().artifact(name).unwrap().clone();
    let mut rng = Rng::new(seed);
    spec.inputs
        .iter()
        .map(|s| {
            if s.name.ends_with("_g") || s.name == "g" {
                HostTensor::ones(&s.shape)
            } else {
                let mut t = HostTensor::zeros(&s.shape);
                rng.fill_normal(&mut t.data, 0.1);
                t
            }
        })
        .collect()
}

#[test]
fn manifest_lists_synthetic_artifacts() {
    let eng = backend();
    assert!(eng.manifest().artifacts.len() >= 40);
    let spec = eng.manifest().find("train_step", "tiny", "preln").unwrap();
    assert_eq!(spec.meta_str("variant"), Some("preln"));
    let schema = eng.manifest().schema("tiny").unwrap();
    let total: usize = schema.iter().map(|p| p.numel()).sum();
    let cfg = eng.manifest().config("tiny").unwrap();
    assert_eq!(total, cfg.n_params);
}

#[test]
fn tp_stage_attn_fwd_shards_sum_to_full_output() {
    // The Megatron invariant the whole schedule rests on: summing per-shard
    // attention outputs (column-sharded wq/wk/wv, row-sharded wo) equals
    // the full (tp = 1) output.
    let eng = backend();
    let cfg = eng.manifest().config("tiny").unwrap().clone();
    let full_name = Manifest::tp_stage_name("tiny", 1, 4, "attn_fwd");
    let full_in = stage_inputs(&eng, &full_name, 7);
    let full = eng.execute(&full_name, &full_in).unwrap();

    let d_attn = cfg.d_model / 2; // tp = 2, kv == h
    let shard_name = Manifest::tp_stage_name("tiny", 2, 4, "attn_fwd");
    let mut sum: Option<HostTensor> = None;
    for r in 0..2usize {
        let inputs = vec![
            full_in[0].clone(),                                   // x
            full_in[1].clone(),                                   // ln1_g
            full_in[2].clone(),                                   // ln1_b
            full_in[3].slice_cols(r * d_attn, (r + 1) * d_attn),  // wq
            full_in[4].slice_cols(r * d_attn, (r + 1) * d_attn),  // wk
            full_in[5].slice_cols(r * d_attn, (r + 1) * d_attn),  // wv
            full_in[6].slice_rows(r * d_attn, (r + 1) * d_attn),  // wo
        ];
        let out = eng.execute(&shard_name, &inputs).unwrap();
        match &mut sum {
            Some(s) => s.add_assign(&out[0]),
            None => sum = Some(out[0].clone()),
        }
    }
    let rel = sum.unwrap().rel_err(&full[0]);
    assert!(rel < 1e-4, "shard sum vs full attention: rel err {rel}");
}

#[test]
fn tp_stage_outputs_match_specs_and_are_finite() {
    let eng = backend();
    for stage in [
        "embed_fwd", "attn_fwd", "mlp_preln_fwd", "mlp_fal_fwd", "lnf_fwd",
        "fal_fused_fwd", "head_fwd_bwd",
    ] {
        let name = Manifest::tp_stage_name("tiny", 2, 4, stage);
        let spec = eng.manifest().artifact(&name).unwrap().clone();
        let mut inputs = stage_inputs(&eng, &name, 11);
        // Token inputs need valid ids, not normal noise.
        let cfg = eng.manifest().config("tiny").unwrap().clone();
        let mut rng = Rng::new(13);
        for (t, s) in inputs.iter_mut().zip(&spec.inputs) {
            if s.dtype == fal::tensor::DType::I32 {
                let ids: Vec<i32> = (0..t.len())
                    .map(|_| rng.below(cfg.vocab_size) as i32)
                    .collect();
                *t = HostTensor::from_i32(&s.shape, &ids);
            }
        }
        let out = eng.execute(&name, &inputs).unwrap();
        assert_eq!(out.len(), spec.outputs.len(), "{stage}");
        for (o, s) in out.iter().zip(&spec.outputs) {
            assert_eq!(o.shape, s.shape, "{stage} output {}", s.name);
            assert!(
                o.data.iter().all(|v| v.is_finite()),
                "{stage}: non-finite output {}",
                s.name
            );
        }
    }
}

/// Central-difference check of a backward stage on the `micro` config: the
/// scalar functional is sum(out ⊙ w) with dout = w, and the gradient wrt
/// input 0 (x) must match (f(x+h) - f(x-h)) / 2h at sampled indices.
fn grad_check(fwd: &str, bwd: &str, dx_index: usize) {
    let eng = backend();
    let fwd_name = Manifest::tp_stage_name("micro", 1, 2, fwd);
    let bwd_name = Manifest::tp_stage_name("micro", 1, 2, bwd);
    let inputs = stage_inputs(&eng, &fwd_name, 21);
    let w = {
        let probe = eng.execute(&fwd_name, &inputs).unwrap();
        let mut rng = Rng::new(22);
        let mut t = HostTensor::zeros(&probe[0].shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    };
    let functional = |ins: &[HostTensor]| -> f64 {
        eng.execute(&fwd_name, ins).unwrap()[0].dot(&w)
    };
    let mut bwd_in = inputs.clone();
    bwd_in.push(w.clone());
    let dx = &eng.execute(&bwd_name, &bwd_in).unwrap()[dx_index];

    let h = 1e-3f32;
    let n = inputs[0].len();
    for i in [0usize, n / 3, n / 2, n - 1] {
        let mut ip = inputs.clone();
        let mut im = inputs.clone();
        ip[0].data[i] += h;
        im[0].data[i] -= h;
        let num = ((functional(&ip) - functional(&im)) / (2.0 * h as f64)) as f32;
        let ana = dx.data[i];
        assert!(
            (num - ana).abs() < 2e-2 * ana.abs().max(1.0),
            "{bwd} dx[{i}]: numeric {num} vs analytic {ana}"
        );
    }
}

#[test]
fn attn_bwd_gradient_check() {
    grad_check("attn_fwd", "attn_bwd", 0);
}

#[test]
fn mlp_preln_bwd_gradient_check() {
    grad_check("mlp_preln_fwd", "mlp_preln_bwd", 0);
}

#[test]
fn mlp_fal_bwd_gradient_check() {
    grad_check("mlp_fal_fwd", "mlp_fal_bwd", 0);
}

#[test]
fn fal_fused_bwd_gradient_check() {
    grad_check("fal_fused_fwd", "fal_fused_bwd", 0);
}

#[test]
fn lnf_bwd_gradient_check() {
    grad_check("lnf_fwd", "lnf_bwd", 0);
}

#[test]
fn train_step_executes_and_reduces_loss() {
    let eng = backend();
    let cfg = eng.manifest().config("tiny").unwrap().clone();
    let spec = eng.manifest().find("train_step", "tiny", "fal").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let np = eng.manifest().schema("tiny").unwrap().len();

    let mut params = eng.load_params("tiny", 0).unwrap();
    let mut m: Vec<HostTensor> =
        params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
    let mut v = m.clone();
    let mut rng = Rng::new(1);
    let tdata: Vec<i32> = (0..batch * cfg.seq_len)
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let tok = HostTensor::from_i32(&[batch, cfg.seq_len], &tdata);
    let mut shifted = tdata.clone();
    shifted.rotate_left(1);
    let tgt = HostTensor::from_i32(&[batch, cfg.seq_len], &shifted);

    let mut first = None;
    let mut last = 0.0f32;
    for step in 1..=8 {
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * np + 4);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(HostTensor::scalar(step as f32));
        inputs.push(HostTensor::scalar(1.0));
        inputs.push(tok.clone());
        inputs.push(tgt.clone());
        let out = eng.execute(&name, &inputs).unwrap();
        let loss = out[0].data[0];
        let gnorm = out[1].data[0];
        assert!(loss.is_finite() && gnorm.is_finite());
        params = out[2..2 + np].to_vec();
        m = out[2 + np..2 + 2 * np].to_vec();
        v = out[2 + 2 * np..2 + 3 * np].to_vec();
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.05,
        "loss did not fall: first {first}, last {last}"
    );
}

#[test]
fn train_step_lr_zero_freezes_params() {
    let eng = backend();
    let spec = eng.manifest().find("train_step", "tiny", "preln").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let cfg = eng.manifest().config("tiny").unwrap().clone();
    let np = eng.manifest().schema("tiny").unwrap().len();
    let params = eng.load_params("tiny", 0).unwrap();
    let zeros: Vec<HostTensor> =
        params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
    let tok = HostTensor::from_i32(
        &[batch, cfg.seq_len],
        &vec![1i32; batch * cfg.seq_len],
    );
    let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * np + 4);
    inputs.extend(params.iter().cloned());
    inputs.extend(zeros.iter().cloned());
    inputs.extend(zeros.iter().cloned());
    inputs.push(HostTensor::scalar(1.0));
    inputs.push(HostTensor::scalar(0.0)); // lr_scale = 0: eval mode
    inputs.push(tok.clone());
    inputs.push(tok.clone());
    let out = eng.execute(&name, &inputs).unwrap();
    for (i, p) in params.iter().enumerate() {
        assert_eq!(&out[2 + i], p, "param {i} moved under lr_scale = 0");
    }
}

#[test]
fn shape_mismatch_rejected() {
    let eng = backend();
    let name = Manifest::tp_stage_name("tiny", 2, 4, "attn_fwd");
    let bad = vec![HostTensor::zeros(&[1])];
    let err = eng.execute(&name, &bad).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
}
