//! ExecCtx determinism contract (the ISSUE's acceptance bar): parallel
//! kernels at threads ∈ {2, 4, 7} must match the threads = 1 scalar path
//! within 0 ulp for the matmul family and within 1e-6 for cross-row
//! reductions, and a fused train step must produce a thread-count-
//! invariant loss. The serial context itself must reproduce the legacy
//! scalar `HostTensor` reference bit-for-bit — that anchor is what keeps
//! every finite-difference and TP-equivalence test meaningful after the
//! kernel rewrite.

use fal::runtime::native::kernels::{self, AttnGeom};
use fal::runtime::{Backend, ExecCtx, NativeBackend, SchedMode};
use fal::tensor::HostTensor;
use fal::util::proptest::Prop;
use fal::util::rng::Rng;

/// The ISSUE-mandated parallel thread counts (7 is deliberately not a
/// power of two: uneven panel splits must not change results).
const PAR_THREADS: [usize; 3] = [2, 4, 7];

fn bits(t: &HostTensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn matmul_family_zero_ulp_across_thread_counts() {
    Prop::new(24).check(
        "matmul family 0 ulp vs serial",
        |r| (1 + r.below(40), (1 + r.below(24), 1 + r.below(48))),
        |&(m, (k, n))| {
            let mut rng = Rng::new((m * 1009 + k * 131 + n) as u64);
            let a = HostTensor::randn(&[m, k], 1.0, &mut rng);
            let b = HostTensor::randn(&[k, n], 1.0, &mut rng);
            let bt = b.transpose(); // [n, k] for the NT variant
            let c = HostTensor::randn(&[m, n], 1.0, &mut rng);
            let s = ExecCtx::serial();
            let mm = kernels::matmul(&s, &a, &b);
            // Serial ctx == legacy scalar reference, bit for bit.
            if bits(&mm) != bits(&a.matmul(&b)) {
                return false;
            }
            let nt = kernels::matmul_nt(&s, &a, &bt);
            let tn = kernels::matmul_tn(&s, &a, &c);
            PAR_THREADS.iter().all(|&t| {
                let ctx = ExecCtx::new(t);
                bits(&kernels::matmul(&ctx, &a, &b)) == bits(&mm)
                    && bits(&kernels::matmul_nt(&ctx, &a, &bt)) == bits(&nt)
                    && bits(&kernels::matmul_tn(&ctx, &a, &c)) == bits(&tn)
            })
        },
    );
}

#[test]
fn matmul_parallel_panels_actually_split() {
    // Shape chosen so even 7 threads get multiple row panels — guards
    // against the work-size floor silently serializing the suite.
    let (m, k, n) = (301usize, 64, 96);
    let ranges = ExecCtx::new(7)
        .chunk_ranges(m, ExecCtx::grain_rows(2 * k * n));
    assert!(ranges.len() > 1, "parallel path not exercised: {ranges:?}");
    let mut rng = Rng::new(77);
    let a = HostTensor::randn(&[m, k], 1.0, &mut rng);
    let b = HostTensor::randn(&[k, n], 1.0, &mut rng);
    let base = kernels::matmul(&ExecCtx::serial(), &a, &b);
    for t in PAR_THREADS {
        assert_eq!(
            bits(&kernels::matmul(&ExecCtx::new(t), &a, &b)),
            bits(&base),
            "threads = {t}"
        );
    }
}

#[test]
fn rowwise_kernels_zero_ulp_across_thread_counts() {
    // Shape floors chosen above the PAR_GRAIN work threshold so the
    // parallel panel paths genuinely split; the generator's smallest shape
    // is asserted to split up front (grain drift would otherwise quietly
    // turn this into serial-vs-serial), and shrunk cases below the floor
    // are skipped rather than vacuously passed off as parallel coverage.
    assert!(
        ExecCtx::new(7)
            .chunk_ranges(160, ExecCtx::grain_rows(6 * 210))
            .len()
            > 1,
        "generator floor no longer splits — raise the test floors"
    );
    Prop::new(12).check(
        "layernorm/softmax/gelu 0 ulp vs serial",
        |r| (160 + r.below(120), 210 + r.below(90)),
        |&(m, n)| {
            if ExecCtx::new(7)
                .chunk_ranges(m, ExecCtx::grain_rows(6 * n))
                .len()
                <= 1
            {
                return true; // shrunk below the split floor
            }
            let mut rng = Rng::new((m * 389 + n) as u64);
            let x = HostTensor::randn(&[m, n], 1.2, &mut rng);
            let g = HostTensor::randn(&[n], 0.4, &mut rng);
            let bt = HostTensor::randn(&[n], 0.2, &mut rng);
            let s = ExecCtx::serial();
            let ln = kernels::layernorm(&s, &x, &g, &bt);
            if bits(&ln) != bits(&x.layernorm(&g, &bt)) {
                return false;
            }
            let sm = kernels::softmax_rows(&s, &x);
            if bits(&sm) != bits(&x.softmax_rows()) {
                return false;
            }
            let ge = kernels::gelu(&s, &x);
            PAR_THREADS.iter().all(|&t| {
                let ctx = ExecCtx::new(t);
                bits(&kernels::layernorm(&ctx, &x, &g, &bt)) == bits(&ln)
                    && bits(&kernels::softmax_rows(&ctx, &x)) == bits(&sm)
                    && bits(&kernels::gelu(&ctx, &x)) == bits(&ge)
            })
        },
    );
}

#[test]
fn reductions_within_1e6_across_thread_counts() {
    // m >= 160 and n >= 210 keep every phase above its PAR_GRAIN floor:
    // layernorm_bwd phase 1 (rows), phase 2 (columns, grain 4m) and
    // sum_rows (columns, grain m) all split at 7 threads. The floor is
    // asserted up front; shrunk sub-floor cases are skipped.
    {
        let seven = ExecCtx::new(7);
        assert!(
            seven.chunk_ranges(160, ExecCtx::grain_rows(10 * 210)).len() > 1
                && seven.chunk_ranges(210, ExecCtx::grain_rows(4 * 160)).len() > 1
                && seven.chunk_ranges(210, ExecCtx::grain_rows(160)).len() > 1,
            "generator floor no longer splits — raise the test floors"
        );
    }
    Prop::new(10).check(
        "layernorm_bwd / sum_rows reductions <= 1e-6 vs serial",
        |r| (160 + r.below(120), 210 + r.below(90)),
        |&(m, n)| {
            let seven = ExecCtx::new(7);
            if seven.chunk_ranges(m, ExecCtx::grain_rows(10 * n)).len() <= 1
                || seven.chunk_ranges(n, ExecCtx::grain_rows(4 * m)).len() <= 1
                || seven.chunk_ranges(n, ExecCtx::grain_rows(m)).len() <= 1
            {
                return true; // shrunk below the split floor
            }
            let mut rng = Rng::new((m * 613 + n) as u64);
            let x = HostTensor::randn(&[m, n], 1.0, &mut rng);
            let g = HostTensor::randn(&[n], 0.5, &mut rng);
            let dout = HostTensor::randn(&[m, n], 1.0, &mut rng);
            let s = ExecCtx::serial();
            let (dx1, dg1, db1) = kernels::layernorm_bwd(&s, &x, &g, &dout);
            let sr1 = kernels::sum_rows(&s, &dout);
            PAR_THREADS.iter().all(|&t| {
                let ctx = ExecCtx::new(t);
                let (dx, dg, db) = kernels::layernorm_bwd(&ctx, &x, &g, &dout);
                let sr = kernels::sum_rows(&ctx, &dout);
                dx.max_abs_err(&dx1) <= 1e-6
                    && dg.max_abs_err(&dg1) <= 1e-6
                    && db.max_abs_err(&db1) <= 1e-6
                    && sr.max_abs_err(&sr1) <= 1e-6
            })
        },
    );
}

#[test]
fn attention_bwd_reductions_within_1e6() {
    // GQA geometry (2 query heads per KV head): dk/dv accumulate across
    // query units, the one place chunk partials reassociate f32 sums.
    let g = AttnGeom { batch: 3, seq: 24, heads: 4, kv_heads: 2, head_dim: 8 };
    // 12 (batch, head) units against a bwd grain of
    // ceil(16384 / (2 * 24^2 * 8)) = 2 units/chunk: genuinely splits.
    assert!(
        ExecCtx::new(7)
            .chunk_ranges(3 * 4, ExecCtx::grain_rows(2 * 24 * 24 * 8))
            .len()
            > 1,
        "attention shape no longer splits — enlarge it"
    );
    let mut rng = Rng::new(91);
    let q = HostTensor::randn(&[3, 24, 32], 0.6, &mut rng);
    let k = HostTensor::randn(&[3, 24, 16], 0.6, &mut rng);
    let v = HostTensor::randn(&[3, 24, 16], 0.6, &mut rng);
    let dout = HostTensor::randn(&[3, 24, 32], 1.0, &mut rng);
    let s = ExecCtx::serial();
    let o1 = kernels::causal_attention(&s, &g, &q, &k, &v);
    let (dq1, dk1, dv1) = kernels::causal_attention_bwd(&s, &g, &q, &k, &v, &dout);
    for t in PAR_THREADS {
        let ctx = ExecCtx::new(t);
        assert_eq!(
            bits(&kernels::causal_attention(&ctx, &g, &q, &k, &v)),
            bits(&o1),
            "fwd threads = {t}"
        );
        let (dq, dk, dv) = kernels::causal_attention_bwd(&ctx, &g, &q, &k, &v, &dout);
        assert_eq!(bits(&dq), bits(&dq1), "dq threads = {t}");
        assert!(dk.max_abs_err(&dk1) <= 1e-6, "dk threads = {t}");
        assert!(dv.max_abs_err(&dv1) <= 1e-6, "dv threads = {t}");
    }
}

/// One fused train step under an explicit context: (loss, gnorm, outputs).
fn fused_step_ctx(ctx: ExecCtx) -> (f32, f32, Vec<HostTensor>) {
    let eng = NativeBackend::synthetic_with_ctx(ctx);
    let cfg = eng.manifest().config("tiny").unwrap().clone();
    let spec = eng.manifest().find("train_step", "tiny", "fal").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let params = eng.load_params("tiny", 0).unwrap();
    let zeros: Vec<HostTensor> =
        params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
    let mut rng = Rng::new(123);
    let toks: Vec<i32> = (0..batch * cfg.seq_len)
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mut shifted = toks.clone();
    shifted.rotate_left(1);
    let mut inputs = params;
    inputs.extend(zeros.iter().cloned());
    inputs.extend(zeros);
    inputs.push(HostTensor::scalar(1.0));
    inputs.push(HostTensor::scalar(1.0));
    inputs.push(HostTensor::from_i32(&[batch, cfg.seq_len], &toks));
    inputs.push(HostTensor::from_i32(&[batch, cfg.seq_len], &shifted));
    let out = eng.execute(&name, &inputs).unwrap();
    (out[0].data[0], out[1].data[0], out)
}

/// [`fused_step_ctx`] at a thread count with the env-default schedule.
fn fused_step_at(threads: usize) -> (f32, f32, Vec<HostTensor>) {
    fused_step_ctx(ExecCtx::new(threads))
}

#[test]
fn fused_train_step_loss_invariant_across_thread_counts() {
    let (loss1, gnorm1, out1) = fused_step_at(1);
    assert!(loss1.is_finite() && gnorm1 > 0.0);
    for t in PAR_THREADS {
        let (loss, gnorm, out) = fused_step_at(t);
        // The forward is built entirely from order-preserving kernels, so
        // the loss is expected to be bit-equal; 1e-6 is the contract bar.
        assert!(
            (loss - loss1).abs() <= 1e-6,
            "threads {t}: loss {loss} vs {loss1}"
        );
        assert!(
            ((gnorm - gnorm1) / gnorm1).abs() <= 1e-4,
            "threads {t}: gnorm {gnorm} vs {gnorm1}"
        );
        // Updated parameters feel the attention dk/dv reassociation
        // *amplified* by AdamW's sign-like g/(sqrt(g^2)+eps) near g = 0,
        // so the parameter bar is one optimizer step (lr = 1e-3), not a
        // kernel-level ulp bound.
        for (i, (a, b)) in out.iter().take(2 + out1.len() / 3).zip(&out1).enumerate()
        {
            assert!(
                a.max_abs_err(b) <= 1e-3,
                "threads {t}: output #{i} drifted beyond one optimizer step"
            );
        }
    }
}

/// The StageGraph acceptance bar: `--sched graph` (branch-parallel
/// MHA ∥ MLP in the fused FAL step) must be **bit-identical** to
/// `--sched serial` at threads {1, 2, 4, 7} — every output of the fused
/// train step, params and optimizer state included. The fork subdivides
/// only the worker pool, never the partition knob, so even the
/// reassociating attention dk/dv reductions combine in the same order.
#[test]
fn graph_sched_bit_identical_to_serial_sched() {
    for threads in [1usize, 2, 4, 7] {
        let (loss_s, gnorm_s, out_s) =
            fused_step_ctx(ExecCtx::new(threads).with_sched(SchedMode::Serial));
        let (loss_g, gnorm_g, out_g) =
            fused_step_ctx(ExecCtx::new(threads).with_sched(SchedMode::Graph));
        assert_eq!(
            loss_s.to_bits(),
            loss_g.to_bits(),
            "threads {threads}: loss diverged across schedules"
        );
        assert_eq!(gnorm_s.to_bits(), gnorm_g.to_bits(), "threads {threads}");
        assert_eq!(out_s.len(), out_g.len());
        for (i, (a, b)) in out_s.iter().zip(&out_g).enumerate() {
            assert_eq!(
                bits(a),
                bits(b),
                "threads {threads}: output #{i} not 0-ulp across schedules"
            );
        }
    }
}

#[test]
fn grad_step_gradients_consistent_across_thread_counts() {
    let run = |threads: usize| -> Vec<HostTensor> {
        let eng = NativeBackend::synthetic_with_threads(threads);
        let cfg = eng.manifest().config("tiny").unwrap().clone();
        let spec = eng.manifest().find("grad_step", "tiny", "preln").unwrap();
        let name = spec.name.clone();
        let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
        let mut inputs = eng.load_params("tiny", 3).unwrap();
        let toks: Vec<i32> =
            (0..batch * cfg.seq_len).map(|i| (i % cfg.vocab_size) as i32).collect();
        let mut shifted = toks.clone();
        shifted.rotate_left(1);
        inputs.push(HostTensor::from_i32(&[batch, cfg.seq_len], &toks));
        inputs.push(HostTensor::from_i32(&[batch, cfg.seq_len], &shifted));
        eng.execute(&name, &inputs).unwrap()
    };
    let base = run(1);
    let par = run(7);
    assert_eq!(base.len(), par.len());
    // Raw gradients (no optimizer): only the attention dk/dv chunk
    // reassociation differs, propagated linearly through the backward.
    for (i, (a, b)) in par.iter().zip(&base).enumerate() {
        assert!(
            a.max_abs_err(b) <= 1e-4,
            "output #{i}: grads drifted across thread counts"
        );
    }
}
