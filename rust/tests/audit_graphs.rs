//! Acceptance for the graph auditor over the *real* trainer schedules:
//! every registered StageGraph — TP preln/fal/falplus forward+backward,
//! the serve decode step at tp 1 and 2, the GPipe pipeline forward, the
//! full pipelined fwd+bwd step graphs
//! (gpipe and 1f1b), the fused FAL block fork — must audit clean (no
//! hard violations, no unused-dependency or unreachable-node lints), and
//! the comm-placement report must reproduce the paper's Fig 2 story:
//! Pre-LN's strict chains fully expose their all-reduces, while FAL's
//! decoupled branches give the scheduler independent compute to hide
//! them behind.

use fal::coordinator::audit::{audit_registered_graphs, GraphAudit};
use fal::runtime::{ExecCtx, KernelTier, NativeBackend, Severity, Violation};

fn audits() -> Vec<GraphAudit> {
    // Pinned to the exact kernel tier: the Fig 2 comm-placement story is
    // a property of the logical schedule, orthogonal to how matmuls are
    // computed, and the fast tier restructures every all-reduce into
    // per-chunk comm drains (`{label}.c{i}` + a gather node) that these
    // label-based assertions are not about. The chunked graphs get their
    // own structural audit in `fast_tier_chunked_graphs_audit_clean`.
    let ctx = ExecCtx::from_env().with_kernels(KernelTier::Exact);
    let eng = NativeBackend::synthetic_with_ctx(ctx);
    audit_registered_graphs(&eng).unwrap()
}

fn find<'a>(audits: &'a [GraphAudit], name: &str) -> &'a GraphAudit {
    audits
        .iter()
        .find(|a| a.name == name)
        .unwrap_or_else(|| panic!("graph {name} not in audit registry"))
}

#[test]
fn registry_covers_every_trainer_schedule() {
    let audits = audits();
    for name in [
        "tp2.preln.fwd",
        "tp2.preln.bwd",
        "tp2.fal.fwd",
        "tp2.fal.bwd",
        "tp2.falplus.fwd",
        "tp2.falplus.bwd",
        "serve.tp1.preln.decode",
        "serve.tp1.fal.decode",
        "serve.tp1.falplus.decode",
        "serve.tp2.preln.decode",
        "serve.tp2.fal.decode",
        "serve.tp2.falplus.decode",
        "pp.gpipe.t2m2.fwd",
        "pp.gpipe.t2m2.step",
        "pp.1f1b.t2m2.step",
        "block.fal_fused.fwd",
        "block.fal_fused.bwd",
    ] {
        find(&audits, name);
    }
}

#[test]
fn planner_top_pick_is_registered_and_clean() {
    // `fal plan`'s top executable pick on the default tiny grid is part
    // of the audit registry under its plan key — the auditor's
    // contracts cover the search output, not just hand-picked layouts —
    // and like every other entry it must be structurally clean.
    let audits = audits();
    let picks: Vec<_> = audits
        .iter()
        .filter(|a| a.name.starts_with("plan.top1."))
        .collect();
    assert!(
        !picks.is_empty(),
        "planner top pick missing from the audit registry"
    );
    for a in picks {
        assert_eq!(
            a.report.hard_count(),
            0,
            "{}: hard violations\n{}",
            a.name,
            a.report.render(&a.name)
        );
    }
}

#[test]
fn all_trainer_graphs_are_structurally_clean() {
    // No hard violations anywhere, and no read-discipline lints: every
    // declared data dependency is actually read through Joined, every
    // node reaches a declared output. (ExposedComm lints are allowed —
    // Pre-LN's serialization IS the paper's claim.)
    for a in audits() {
        assert_eq!(
            a.report.hard_count(),
            0,
            "{}: hard violations\n{}",
            a.name,
            a.report.render(&a.name)
        );
        for v in &a.report.violations {
            assert!(
                matches!(v, Violation::ExposedComm { .. }),
                "{}: unexpected lint {v}",
                a.name
            );
        }
    }
}

#[test]
fn preln_forward_comm_is_fully_exposed() {
    // The Fig 2 anti-pattern, detected statically: every all-reduce in
    // the Pre-LN forward sits on the critical path with zero independent
    // compute, and the report prices the exposure in link-seconds.
    let audits = audits();
    let a = find(&audits, "tp2.preln.fwd");
    assert!(!a.report.comm.is_empty(), "no comm nodes in preln fwd");
    for c in &a.report.comm {
        assert!(
            c.hideable_secs == 0.0 && c.hidden_fraction == 0.0,
            "{}: preln comm {} unexpectedly hideable",
            a.name,
            c.label
        );
    }
    let exposed: Vec<_> = a
        .report
        .violations
        .iter()
        .filter(|v| matches!(v, Violation::ExposedComm { .. }))
        .collect();
    assert_eq!(
        exposed.len(),
        a.report.comm.len(),
        "every preln fwd all-reduce should be flagged"
    );
    assert!(a.report.exposed_secs() > 0.0);
    for v in &exposed {
        assert_eq!(v.severity(), Severity::Lint);
    }
}

#[test]
fn fal_backward_hides_comm_behind_independent_compute() {
    // FAL's point: dfa partials and the next block's fused backward are
    // independent of the in-flight dx all-reduce, so the auditor finds
    // hideable compute for (at least) the inner-block collectives.
    let audits = audits();
    let a = find(&audits, "tp2.fal.bwd");
    let hideable = a
        .report
        .comm
        .iter()
        .filter(|c| c.hideable_secs > 0.0 && c.hidden_fraction > 0.0)
        .count();
    assert!(
        hideable > 0,
        "{}: no hideable collective found\n{}",
        a.name,
        a.report.render(&a.name)
    );
    // And FAL exposes strictly less predicted comm than Pre-LN's bwd.
    let preln = find(&audits, "tp2.preln.bwd");
    assert!(
        a.report.exposed_secs() < preln.report.exposed_secs(),
        "fal bwd exposed {} vs preln bwd {}",
        a.report.exposed_secs(),
        preln.report.exposed_secs()
    );
}

#[test]
fn falplus_lnf_overlaps_the_attention_allreduce() {
    // FAL+ main blocks: lnf_fwd depends only on the block-1 signal, so
    // the MHA all-reduce of every main block has independent compute.
    let audits = audits();
    let a = find(&audits, "tp2.falplus.fwd");
    let main_ars: Vec<_> = a
        .report
        .comm
        .iter()
        .filter(|c| c.label.ends_with(".ar.attn") && c.label != "L0.ar.attn")
        .collect();
    assert!(!main_ars.is_empty(), "no main-block attn all-reduces");
    for c in main_ars {
        assert!(
            c.hideable_secs > 0.0,
            "{}: {} has nothing to hide behind",
            a.name,
            c.label
        );
    }
}

#[test]
fn serve_decode_keeps_the_fig2_comm_story() {
    // The decode step inherits the training schedule's structure: FAL+
    // main blocks' per-token MHA all-reduce has the LNf_i node (which
    // depends only on the block-1 signal) as independent compute, and
    // FAL's fused decode blocks need strictly fewer collectives per
    // token than Pre-LN's.
    let audits = audits();
    let a = find(&audits, "serve.tp2.falplus.decode");
    let main_ars: Vec<_> = a
        .report
        .comm
        .iter()
        .filter(|c| c.label.ends_with(".ar.attn") && c.label != "L0.ar.attn")
        .collect();
    assert!(!main_ars.is_empty(), "no main-block decode attn all-reduces");
    for c in main_ars {
        assert!(
            c.hideable_secs > 0.0,
            "{}: decode {} has nothing to hide behind",
            a.name,
            c.label
        );
    }
    let fal = find(&audits, "serve.tp2.fal.decode");
    let preln = find(&audits, "serve.tp2.preln.decode");
    assert!(
        !fal.report.comm.is_empty() && !preln.report.comm.is_empty(),
        "decode graphs lost their comm nodes"
    );
    assert!(
        fal.report.comm.len() < preln.report.comm.len(),
        "fal decode {} ARs vs preln {}",
        fal.report.comm.len(),
        preln.report.comm.len()
    );
}

#[test]
fn pipeline_step_reversed_sends_report_hideable_comm() {
    // The full fwd+bwd step graphs: the reversed P2P gradient sends
    // (bsend[...]) are comm nodes like any other, and under both
    // linearizations the auditor finds compute that is neither upstream
    // nor downstream of them — the other micro-batch's cells — so the
    // overlap scheduler has something to hide them behind.
    let audits = audits();
    for name in ["pp.gpipe.t2m2.step", "pp.1f1b.t2m2.step"] {
        let a = find(&audits, name);
        let bsends: Vec<_> = a.report.comm_with_prefix("bsend[").collect();
        assert_eq!(
            bsends.len(),
            2,
            "{name}: one reversed send per (micro-batch, boundary)\n{}",
            a.report.render(name)
        );
        assert!(
            bsends.iter().any(|c| c.hideable_secs > 0.0),
            "{name}: no reversed send has independent compute\n{}",
            a.report.render(name)
        );
        // Forward sends are still present and also priced.
        assert_eq!(a.report.comm_with_prefix("send[").count(), 2, "{name}");
    }
}

#[test]
fn pipeline_ordering_edges_do_not_trip_the_unused_lint() {
    // The GPipe device-exclusivity edges are ordering-only deps — the
    // cells never read them — and sends overlap the next cell's compute.
    let audits = audits();
    let a = find(&audits, "pp.gpipe.t2m2.fwd");
    assert!(
        !a.report
            .violations
            .iter()
            .any(|v| matches!(v, Violation::UnusedDep { .. })),
        "ordering deps leaked into the unused-dep lint\n{}",
        a.report.render(&a.name)
    );
    assert!(
        a.report.comm.iter().any(|c| c.hideable_secs > 0.0),
        "no pipeline send overlaps any cell"
    );
}

#[test]
fn fast_tier_chunked_graphs_audit_clean() {
    // Under `--kernels fast` every TP/serve all-reduce is emitted as
    // AR_CHUNKS per-chunk comm drains plus a compute gather node that
    // inherits the original label. The chunked graphs must stay
    // structurally clean — the gather reads every chunk and the shape
    // dep, so no hard violations and no read-discipline lints appear —
    // and the chunk drains must actually be there.
    let ctx = ExecCtx::from_env().with_kernels(KernelTier::Fast);
    let eng = NativeBackend::synthetic_with_ctx(ctx);
    let audits = audit_registered_graphs(&eng).unwrap();
    for a in &audits {
        assert_eq!(
            a.report.hard_count(),
            0,
            "{}: hard violations under the fast tier\n{}",
            a.name,
            a.report.render(&a.name)
        );
        for v in &a.report.violations {
            assert!(
                matches!(v, Violation::ExposedComm { .. }),
                "{}: unexpected fast-tier lint {v}",
                a.name
            );
        }
    }
    // The falplus forward's main-block attention all-reduces are now
    // chunk drains: labels carry a `.c{i}` suffix, and the bare `.ar.*`
    // label has moved to the (non-comm) gather node.
    let a = find(&audits, "tp2.falplus.fwd");
    let chunk_drains = a
        .report
        .comm
        .iter()
        .filter(|c| c.label.contains(".ar.attn.c"))
        .count();
    assert!(
        chunk_drains >= 2,
        "{}: expected per-chunk attn all-reduce drains, got comm {:?}",
        a.name,
        a.report.comm.iter().map(|c| &c.label).collect::<Vec<_>>()
    );
    assert!(
        !a.report.comm.iter().any(|c| c.label.ends_with(".ar.attn")),
        "{}: unchunked attn all-reduce leaked into the fast tier",
        a.name
    );
}
