//! Integration: load real AOT artifacts, execute them, check numerics.
//!
//! Requires the `pjrt` feature plus `make artifacts` (tiny group). These
//! tests are the Rust half of the AOT contract: if the manifest, HLO text,
//! parameter snapshot or the engine's conversion layer drift, they fail
//! here first. The native-backend equivalents live in
//! rust/tests/native_backend.rs and run on default features.
#![cfg(feature = "pjrt")]

use std::path::Path;

use fal::runtime::{Backend, Engine};
use fal::tensor::HostTensor;
use fal::util::rng::Rng;

fn engine() -> Engine {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::new(&dir).expect("run `make artifacts` before cargo test")
}

fn tokens(cfg: &fal::config::ModelConfig, batch: usize, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let data: Vec<i32> = (0..batch * cfg.seq_len)
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    HostTensor::from_i32(&[batch, cfg.seq_len], &data)
}

#[test]
fn manifest_lists_tiny_artifacts() {
    let eng = engine();
    assert!(eng.manifest.artifacts.len() >= 10);
    let spec = eng.manifest.find("train_step", "tiny", "preln").unwrap();
    assert_eq!(spec.meta_str("variant"), Some("preln"));
    let schema = eng.manifest.schema("tiny").unwrap();
    let total: usize = schema.iter().map(|p| p.numel()).sum();
    let cfg = eng.manifest.config("tiny").unwrap();
    assert_eq!(total, cfg.n_params);
}

#[test]
fn params_snapshot_loads_and_has_ln_ones() {
    let eng = engine();
    let params = eng.manifest.load_params("tiny", 0).unwrap();
    let schema = eng.manifest.schema("tiny").unwrap();
    // Any LN gamma leaf must be exactly 1.0 at init.
    let idx = schema
        .iter()
        .position(|p| p.name.ends_with("ln1_g"))
        .unwrap();
    assert!(params[idx].data.iter().all(|&v| v == 1.0));
    // Embeddings must be small random values.
    let wte = schema.iter().position(|p| p.name == "wte").unwrap();
    assert!(params[wte].norm() > 0.0);
    assert!(params[wte].mean_abs() < 0.1);
}

#[test]
fn train_step_executes_and_reduces_loss() {
    let eng = engine();
    let cfg = eng.manifest.config("tiny").unwrap().clone();
    let spec = eng.manifest.find("train_step", "tiny", "fal").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let np = eng.manifest.schema("tiny").unwrap().len();

    let mut params = eng.manifest.load_params("tiny", 0).unwrap();
    let mut m: Vec<HostTensor> =
        params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
    let mut v = m.clone();
    let tok = tokens(&cfg, batch, 1);
    // Next-token targets: shift by one (wrapping) — same batch every step so
    // the loss must fall fast.
    let mut tdata = tok.as_i32();
    tdata.rotate_left(1);
    let tgt = HostTensor::from_i32(&[batch, cfg.seq_len], &tdata);

    let mut first = None;
    let mut last = 0.0f32;
    for step in 1..=8 {
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 * np + 4);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(HostTensor::scalar(step as f32));
        inputs.push(HostTensor::scalar(1.0));
        inputs.push(tok.clone());
        inputs.push(tgt.clone());
        let out = eng.execute(&name, &inputs).unwrap();
        // outputs: loss, gnorm, params x np, m x np, v x np
        let loss = out[0].data[0];
        let gnorm = out[1].data[0];
        assert!(loss.is_finite() && gnorm.is_finite());
        params = out[2..2 + np].to_vec();
        m = out[2 + np..2 + 2 * np].to_vec();
        v = out[2 + 2 * np..2 + 3 * np].to_vec();
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.05,
        "loss did not fall: first {first}, last {last}"
    );
}

#[test]
fn eval_masked_gates_change_loss() {
    let eng = engine();
    let cfg = eng.manifest.config("tiny").unwrap().clone();
    let spec = eng.manifest.find("eval_masked", "tiny", "preln").unwrap();
    let name = spec.name.clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let l = cfg.n_layer;

    let params = eng.manifest.load_params("tiny", 0).unwrap();
    let tok = tokens(&cfg, batch, 2);
    let mut tdata = tok.as_i32();
    tdata.rotate_left(1);
    let tgt = HostTensor::from_i32(&[batch, cfg.seq_len], &tdata);

    let run = |mha: f32, conn: f32| -> (f32, f32) {
        let mut inputs = params.clone();
        inputs.push(tok.clone());
        inputs.push(tgt.clone());
        inputs.push(HostTensor::from_vec(&[l], vec![mha; l]));
        inputs.push(HostTensor::from_vec(&[l], vec![conn; l]));
        let out = eng.execute(&name, &inputs).unwrap();
        (out[0].data[0], out[1].data[0])
    };

    let (full, count) = run(1.0, 1.0);
    let (gated, _) = run(0.0, 0.0);
    assert_eq!(count, (batch * cfg.seq_len) as f32);
    assert!(full.is_finite() && gated.is_finite());
    assert!((full - gated).abs() > 1e-3, "gates had no effect");
}

#[test]
fn tp_stage_attn_fwd_shards_sum_is_consistent() {
    let eng = engine();
    let cfg = eng.manifest.config("tiny").unwrap().clone();
    let name = fal::runtime::Manifest::tp_stage_name("tiny", 2, 4, "attn_fwd");
    let spec = eng.manifest.artifact(&name).unwrap().clone();
    let mut rng = Rng::new(3);
    // Random inputs matching the stage spec.
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| {
            let mut t = HostTensor::zeros(&s.shape);
            rng.fill_normal(&mut t.data, 0.05);
            // LN gammas should be ~1 for realism.
            if s.shape.len() == 1 && s.shape[0] == cfg.d_model {
                t.data.fill(1.0);
            }
            t
        })
        .collect();
    let out = eng.execute(&name, &inputs).unwrap();
    assert_eq!(out[0].shape, vec![4, cfg.seq_len, cfg.d_model]);
    assert!(out[0].data.iter().all(|v| v.is_finite()));
}

#[test]
fn engine_reports_stats() {
    let eng = engine();
    let spec = eng.manifest.find("eval_masked", "tiny", "preln").unwrap();
    let name = spec.name.clone();
    let params = eng.manifest.load_params("tiny", 0).unwrap();
    let cfg = eng.manifest.config("tiny").unwrap().clone();
    let batch = spec.meta.get("batch").unwrap().as_usize().unwrap();
    let mut inputs = params;
    let tok = tokens(&cfg, batch, 4);
    inputs.push(tok.clone());
    inputs.push(tok.clone());
    inputs.push(HostTensor::ones(&[cfg.n_layer]));
    inputs.push(HostTensor::ones(&[cfg.n_layer]));
    eng.execute(&name, &inputs).unwrap();
    let stats = eng.stats();
    let s = stats.get(&name).unwrap();
    assert_eq!(s.calls, 1);
    assert!(s.exec_secs > 0.0);
    assert!(eng.stats_report().contains(&name));
}

#[test]
fn shape_mismatch_rejected() {
    let eng = engine();
    let spec = eng.manifest.find("eval_masked", "tiny", "preln").unwrap();
    let bad = vec![HostTensor::zeros(&[1])];
    let err = eng.execute(&spec.name.clone(), &bad).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
fn buffer_roundtrip() {
    let eng = engine();
    let t = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let buf = eng.upload(&t).unwrap();
    let back = eng.download(&buf).unwrap();
    assert_eq!(back, t);
}
