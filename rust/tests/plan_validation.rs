//! Differential harness for `fal plan`: the planner's enumeration is
//! deterministic (bitwise-identical table across runs — and across
//! FAL_THREADS, since the ranking is a pure function with no
//! environment input; the CI matrix re-runs this suite at 1 and 4
//! threads to witness it), pruning never drops the exhaustive optimum,
//! and the top executed picks' realized step times stay within the plan
//! table's reported tolerance — the execution-validated-cost-model
//! contract of the PR.

use fal::config::Variant;
use fal::coordinator::dp_pp::PpSched;
use fal::coordinator::planner::{
    self, enumerate_layouts, ClusterSpec, Layout,
};
use fal::runtime::{Backend, NativeBackend, SchedMode};

fn tiny_cfg(engine: &NativeBackend) -> fal::config::ModelConfig {
    engine.manifest().config("tiny").unwrap().clone()
}

#[test]
fn tiny_grid_ranks_at_least_24_layouts() {
    // The acceptance grid: tiny on 4 simulated PCIe 3090s at batch 4.
    let engine = NativeBackend::synthetic();
    let cfg = tiny_cfg(&engine);
    let cluster = ClusterSpec::pcie_3090(4);
    let p = planner::plan(&cfg, &cluster, 4, planner::DEFAULT_VARIANTS);
    assert!(p.entries.len() >= 24, "only {} layouts", p.entries.len());
    // Enough executable frontier picks for the CLI's default --top 2.
    assert!(
        p.executable_picks(2).len() >= 2,
        "fewer than 2 executable frontier picks"
    );
}

#[test]
fn plan_table_is_bitwise_deterministic() {
    // Two independent invocations (fresh enumeration, scoring, pruning
    // and sort) must render the exact same bytes. The planner takes no
    // engine, clock, or environment input — FAL_THREADS cannot reach
    // it, which is what makes the CI t1/t4 matrix legs byte-comparable.
    let engine = NativeBackend::synthetic();
    let cfg = tiny_cfg(&engine);
    let cluster = ClusterSpec::pcie_3090(4);
    let a = planner::plan(&cfg, &cluster, 4, planner::DEFAULT_VARIANTS);
    let b = planner::plan(&cfg, &cluster, 4, planner::DEFAULT_VARIANTS);
    assert_eq!(
        a.render_table().render_text(),
        b.render_table().render_text()
    );
    let keys = |p: &planner::Plan| -> Vec<String> {
        p.entries.iter().map(|e| e.layout.key()).collect()
    };
    assert_eq!(keys(&a), keys(&b));
}

#[test]
fn pruning_never_drops_the_true_optimum() {
    // Exhaustive-vs-pruned differential over several small grids: the
    // unpruned argmin by step time must survive dominance marking and
    // sit at rank 1.
    let engine = NativeBackend::synthetic();
    let cfg = tiny_cfg(&engine);
    for gpus in [2usize, 4, 8] {
        for batch in [4usize, 8] {
            let cluster = ClusterSpec::pcie_3090(gpus);
            let p = planner::plan(
                &cfg, &cluster, batch, planner::DEFAULT_VARIANTS,
            );
            assert!(!p.entries.is_empty(), "empty grid at gpus {gpus}");
            // Exhaustive search over the raw (pre-ranking) enumeration.
            let exhaustive = enumerate_layouts(
                &cfg, &cluster, batch, planner::DEFAULT_VARIANTS,
            )
            .iter()
            .map(|l| planner::score_layout(&cfg, &cluster, batch, l))
            .fold(f64::INFINITY, |acc, e| acc.min(e.time.step));
            let top = &p.entries[0];
            assert_eq!(
                top.time.step, exhaustive,
                "gpus {gpus} batch {batch}: rank-1 is not the optimum"
            );
            assert!(
                !top.dominated,
                "gpus {gpus} batch {batch}: optimum was pruned"
            );
            // Pareto sanity: every pruned point has a surviving witness
            // at least as good on both axes.
            let frontier = p.frontier();
            for e in p.entries.iter().filter(|e| e.dominated) {
                assert!(
                    frontier.iter().any(|f| f.time.step <= e.time.step
                        && f.mem_bytes <= e.mem_bytes),
                    "{}: dominated without frontier witness",
                    e.layout.key()
                );
            }
        }
    }
}

#[test]
fn frontier_prefers_overlap_and_fal_on_pcie() {
    // Structural differential on the scored table itself: every layout
    // on 4 GPUs pays some comm, so the overlap variant of any layout
    // strictly beats its serial twin — rank 1 must be an overlap
    // schedule — and FAL's best never trails Pre-LN's best.
    let engine = NativeBackend::synthetic();
    let cfg = tiny_cfg(&engine);
    let cluster = ClusterSpec::pcie_3090(4);
    let p = planner::plan(&cfg, &cluster, 4, planner::DEFAULT_VARIANTS);
    assert_eq!(p.entries[0].layout.sched, SchedMode::Overlap);
    let best = |v: Variant| {
        p.entries
            .iter()
            .filter(|e| e.layout.variant == v)
            .map(|e| e.time.step)
            .fold(f64::INFINITY, f64::min)
    };
    assert!(best(Variant::Fal) <= best(Variant::PreLn));
}

#[test]
fn executed_picks_within_reported_tolerance() {
    // The PR's contract end-to-end: take the plan's top executable
    // frontier picks, run them through the real TpTrainer/PpTrainer
    // step schedules, and require |predicted − realized| / realized
    // within the table's tolerance for every pick.
    let engine = NativeBackend::synthetic();
    let cfg = tiny_cfg(&engine);
    let cluster = ClusterSpec::pcie_3090(4);
    let p = planner::plan(&cfg, &cluster, 4, planner::DEFAULT_VARIANTS);
    let picks: Vec<Layout> =
        p.executable_picks(2).iter().map(|e| e.layout).collect();
    assert_eq!(picks.len(), 2);
    let v = planner::validate_layouts(&engine, &p, &picks, 2, 25.0).unwrap();
    assert!(v.calibration_secs > 0.0);
    assert!(v.secs_per_flop > 0.0);
    for pick in &v.picks {
        assert!(pick.realized_secs > 0.0, "{}", pick.layout.key());
        assert!(pick.predicted_secs > 0.0, "{}", pick.layout.key());
        assert!(
            !pick.plan_secs.is_nan(),
            "{}: executed layout missing from the plan",
            pick.layout.key()
        );
        assert!(
            pick.rel_err <= v.tolerance,
            "{}: rel err {:.3} above tol {:.2} (predicted {:.4}s, \
             realized {:.4}s)",
            pick.layout.key(),
            pick.rel_err,
            v.tolerance,
            pick.predicted_secs,
            pick.realized_secs
        );
    }
}

#[test]
fn predicted_ranking_agrees_with_realized_on_contrasting_layouts() {
    // The differential the planner exists for: Pre-LN vs FAL at tp=2
    // under a heavy simulated link. The virtual clock charges Pre-LN
    // ~16 all-reduce drains per step and FAL ~11 on the 4-layer tiny
    // config, so with the drains scaled far above compute noise the
    // realized ordering must match the predicted one.
    let engine = NativeBackend::synthetic();
    let cfg = tiny_cfg(&engine);
    let cluster = ClusterSpec::pcie_3090(4);
    let p = planner::plan(&cfg, &cluster, 4, planner::DEFAULT_VARIANTS);
    let mk = |variant| Layout {
        dp: 1,
        tp: 2,
        pp: 1,
        micro: 1,
        sched: SchedMode::Serial,
        pp_sched: PpSched::GPipe,
        variant,
    };
    let picks = [mk(Variant::PreLn), mk(Variant::Fal)];
    let v = planner::validate_layouts(&engine, &p, &picks, 2, 600.0).unwrap();
    let preln = &v.picks[0];
    let fal = &v.picks[1];
    assert!(
        preln.predicted_secs > fal.predicted_secs,
        "cost model lost the Fig 2 inequality"
    );
    assert!(
        preln.realized_secs > fal.realized_secs,
        "realized: preln {:.4}s !> fal {:.4}s (comm drains too small \
         vs compute noise?)",
        preln.realized_secs,
        fal.realized_secs
    );
    assert!(v.rank_agreement(), "predicted and realized orderings differ");
}
