//! Correctness anchor for `fal serve`: the KV-cache decode loop must
//! reproduce the full-sequence forward **bit for bit**, position by
//! position — the decode analogue of tests/tp_equivalence.rs.
//!
//! The reference forward below composes the public full-sequence stage
//! kernels (`embed_fwd`/`attn_fwd`/`mlp_fwd`/`layernorm`/`matmul_nt`) in
//! the exact residual order the trainers use; the decode path re-derives
//! every row incrementally against its K/V cache. Equality is
//! `f32::to_bits` at multiple thread counts for all three TP variants,
//! plus 0-ulp agreement across `--sched serial|graph|overlap`, a
//! tp=2-vs-tp=1 reassociation tolerance, and the acceptance workload:
//! a ≥200-request continuous-batching run per (variant, tp).

use fal::config::{Variant, PCIE_GEN4, RTX_3090};
use fal::coordinator::serve::{poisson_workload, Decoder, ServeEngine};
use fal::coordinator::topology::NamedParams;
use fal::runtime::native::kernels::{layernorm, matmul_nt, AttnGeom};
use fal::runtime::native::stages::{attn_fwd, embed_fwd, mlp_fwd};
use fal::runtime::{Backend, ExecCtx, NativeBackend, SchedMode};
use fal::tensor::HostTensor;

const CONFIG: &str = "micro";
const VARIANTS: [Variant; 3] = [Variant::PreLn, Variant::Fal, Variant::FalPlus];

fn deterministic_tokens(b: usize, s: usize, vocab: usize) -> Vec<i32> {
    (0..b * s).map(|i| ((i * 7 + 3) % vocab) as i32).collect()
}

/// Full-sequence forward logits `[B, S, V]` from the same parameters the
/// decoder loads, composed in the trainers' residual order.
fn reference_logits(
    eng: &NativeBackend,
    variant: Variant,
    toks: &[i32],
    b: usize,
) -> HostTensor {
    let ctx = eng.exec_ctx();
    let cfg = eng.manifest().config(CONFIG).unwrap().clone();
    let schema = eng.manifest().schema(CONFIG).unwrap().to_vec();
    let params = NamedParams::from_flat(&schema, eng.load_params(CONFIG, 0).unwrap());
    let s = cfg.seq_len;
    let tok_t = HostTensor::from_i32(&[b, s], toks);
    let mut x = embed_fwd(
        &ctx,
        &tok_t,
        params.get("wte").unwrap(),
        params.get("wpe").unwrap(),
    );
    let g = AttnGeom {
        batch: b,
        seq: s,
        heads: cfg.n_head,
        kv_heads: cfg.n_kv_head,
        head_dim: cfg.head_dim(),
    };
    let mut fa: Option<HostTensor> = None;
    for li in 0..cfg.n_layer {
        let ap: Vec<&HostTensor> = ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo"]
            .iter()
            .map(|f| params.blk(li, f).unwrap())
            .collect();
        let mp: Vec<&HostTensor> = ["ln2_g", "ln2_b", "w1", "b1", "w2", "b2"]
            .iter()
            .map(|f| params.blk(li, f).unwrap())
            .collect();
        let a = attn_fwd(&ctx, &g, &x, &ap).out;
        match (variant, li) {
            (Variant::PreLn, _) => {
                let mut h = x.clone();
                h.add_assign(&a);
                let m = mlp_fwd(&ctx, &h, None, &mp).out;
                h.add_assign(&m);
                x = h;
            }
            (Variant::Fal, 0) => {
                let f = layernorm(
                    &ctx,
                    &a,
                    params.blk(0, "lnf_g").unwrap(),
                    params.blk(0, "lnf_b").unwrap(),
                );
                let m = mlp_fwd(&ctx, &x, Some(&f), &mp).out;
                x.add_assign(&a);
                x.add_assign(&m);
                fa = Some(f);
            }
            (Variant::Fal, _) => {
                // fal_fused_fwd semantics: out = a + m, then x + out.
                let m = mlp_fwd(&ctx, &x, fa.as_ref(), &mp).out;
                let mut out = a.clone();
                out.add_assign(&m);
                x.add_assign(&out);
            }
            (Variant::FalPlus, 0) => {
                let m = mlp_fwd(&ctx, &x, Some(&a), &mp).out;
                x.add_assign(&a);
                x.add_assign(&m);
                fa = Some(a);
            }
            (Variant::FalPlus, _) => {
                let mut h = x.clone();
                h.add_assign(&a);
                let fan = layernorm(
                    &ctx,
                    fa.as_ref().unwrap(),
                    params.blk(li, "lnf_g").unwrap(),
                    params.blk(li, "lnf_b").unwrap(),
                );
                let m = mlp_fwd(&ctx, &h, Some(&fan), &mp).out;
                h.add_assign(&m);
                x = h;
            }
            _ => unreachable!(),
        }
    }
    let xn = layernorm(
        &ctx,
        &x,
        params.get("lnF_g").unwrap(),
        params.get("lnF_b").unwrap(),
    );
    matmul_nt(&ctx, &xn, params.get("wte").unwrap())
}

/// Teacher-forced decode: feed token column `p` at position `p` for every
/// slot; returns one `[B, V]` logits tensor per position.
fn decode_all_positions(
    dec: &mut Decoder<'_, NativeBackend>,
    toks: &[i32],
    s: usize,
) -> Vec<HostTensor> {
    let b = dec.batch;
    (0..s)
        .map(|p| {
            let col: Vec<i32> = (0..b).map(|bi| toks[bi * s + p]).collect();
            dec.step(&col, &vec![p; b]).unwrap()
        })
        .collect()
}

#[test]
fn decode_matches_full_forward_bitwise() {
    for threads in [1usize, 4] {
        let eng = NativeBackend::synthetic_with_ctx(ExecCtx::new(threads));
        for variant in VARIANTS {
            let mut dec =
                Decoder::new(&eng, CONFIG, variant, 1, PCIE_GEN4).unwrap();
            let (b, s, v) =
                (dec.batch, dec.cfg.seq_len, dec.cfg.vocab_size);
            let toks = deterministic_tokens(b, s, v);
            let full = reference_logits(&eng, variant, &toks, b);
            let steps = decode_all_positions(&mut dec, &toks, s);
            for (p, logits) in steps.iter().enumerate() {
                assert_eq!(logits.shape, vec![b, v]);
                for bi in 0..b {
                    let got = &logits.data[bi * v..][..v];
                    let want = &full.data[(bi * s + p) * v..][..v];
                    let eq = got
                        .iter()
                        .zip(want)
                        .all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(
                        eq,
                        "{} t{threads} pos {p} slot {bi}: decode logits \
                         diverge from full forward",
                        variant.name()
                    );
                }
            }
        }
    }
}

#[test]
fn decode_identical_across_sched_modes() {
    // serial / graph / overlap (with a nonzero simulated drain) must be
    // 0-ulp identical — the same contract the training graphs keep.
    let mut per_sched: Vec<Vec<u32>> = Vec::new();
    for sched in [SchedMode::Serial, SchedMode::Graph, SchedMode::Overlap] {
        let eng = NativeBackend::synthetic_with_ctx(
            ExecCtx::new(2).with_sched(sched),
        );
        let mut dec =
            Decoder::new(&eng, CONFIG, Variant::Fal, 2, PCIE_GEN4).unwrap();
        dec.comm_sim_scale = 1.0;
        let (b, s, v) = (dec.batch, dec.cfg.seq_len, dec.cfg.vocab_size);
        let toks = deterministic_tokens(b, s, v);
        let bits: Vec<u32> = decode_all_positions(&mut dec, &toks, s)
            .iter()
            .flat_map(|t| t.data.iter().map(|x| x.to_bits()))
            .collect();
        per_sched.push(bits);
    }
    assert_eq!(per_sched[0], per_sched[1], "serial vs graph");
    assert_eq!(per_sched[0], per_sched[2], "serial vs overlap");
}

#[test]
fn tp2_decode_matches_tp1_up_to_reassociation() {
    let eng = NativeBackend::synthetic();
    for variant in VARIANTS {
        let run = |tp: usize| {
            let mut dec =
                Decoder::new(&eng, CONFIG, variant, tp, PCIE_GEN4).unwrap();
            let (b, s, v) = (dec.batch, dec.cfg.seq_len, dec.cfg.vocab_size);
            let toks = deterministic_tokens(b, s, v);
            decode_all_positions(&mut dec, &toks, s)
        };
        let (t1, t2) = (run(1), run(2));
        for (p, (a, b_)) in t1.iter().zip(&t2).enumerate() {
            let max = a
                .data
                .iter()
                .zip(&b_.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max < 1e-3,
                "{} pos {p}: tp2 deviates from tp1 by {max}",
                variant.name()
            );
        }
    }
}

#[test]
fn serve_completes_200_requests_every_variant_and_tp() {
    // The acceptance workload: a 200-request continuous-batching run per
    // (variant, tp) must drain completely with sane statistics.
    let eng = NativeBackend::synthetic();
    for variant in VARIANTS {
        for tp in [1usize, 2] {
            let dec =
                Decoder::new(&eng, CONFIG, variant, tp, PCIE_GEN4).unwrap();
            let cfg = dec.cfg.clone();
            let reqs = poisson_workload(&cfg, 200, 11, 500.0);
            let mut srv = ServeEngine::new(dec, RTX_3090);
            let r = srv.run(&reqs).unwrap();
            assert_eq!(
                r.completed,
                200,
                "{} tp{tp}: incomplete drain",
                variant.name()
            );
            assert!(r.generated_tokens >= 200);
            assert!(r.tokens_per_sec > 0.0);
            assert!(r.mean_occupancy > 0.0 && r.mean_occupancy <= 1.0);
            assert!(r.p99_token_secs >= r.p50_token_secs);
            assert!(r.p99_ttft_secs >= r.p50_ttft_secs);
            assert!(r.useful_flops > 0.0);
            if tp >= 2 {
                assert!(r.allreduces > 0, "{} tp{tp}", variant.name());
            }
        }
    }
}

#[test]
fn fal_decode_moves_fewer_bytes_than_preln() {
    // The paper's claim at generation time: FAL's 1-AR/block schedule
    // roughly halves per-token collective volume under TP.
    let eng = NativeBackend::synthetic();
    let comm = |variant: Variant| {
        let mut dec =
            Decoder::new(&eng, CONFIG, variant, 2, PCIE_GEN4).unwrap();
        let (b, s, v) = (dec.batch, dec.cfg.seq_len, dec.cfg.vocab_size);
        let toks = deterministic_tokens(b, s, v);
        decode_all_positions(&mut dec, &toks, s);
        dec.ledger.stats().allreduce_bytes
    };
    let preln = comm(Variant::PreLn);
    let fal = comm(Variant::Fal);
    assert!(fal < preln, "fal {fal} vs preln {preln}");
    let l = eng.manifest().config(CONFIG).unwrap().n_layer as f64;
    let expect = (l + 1.0) / (2.0 * l);
    let ratio = fal / preln;
    assert!(
        (ratio - expect).abs() < 1e-6,
        "AR byte ratio {ratio} != (L+1)/2L = {expect}"
    );
}
