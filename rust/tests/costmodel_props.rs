//! Property tests for the costmodel primitives `fal plan` ranks with.
//!
//! The planner trusts the timemodel blindly — if a primitive violates
//! its bounds or loses monotonicity, the search silently returns wrong
//! layouts, so every load-bearing shape gets pinned here: fraction
//! bounds, step-time monotonicity in batch and model size, the
//! tp-scaling crossover where the comm term takes over, dtype scaling
//! of the decode model, and the paper's core inequality (FAL predicts
//! strictly less TP comm than Pre-LN at every tp ≥ 2).

use fal::config::{
    ModelConfig, Variant, H200, NVLINK, PCIE_GEN4, RTX_3090,
};
use fal::costmodel::timemodel::{
    decode_step_time_dtyped, layout_peak_mem_bytes, layout_step_time,
    pipeline_bubble_fraction, predicted_hidden_fraction, train_step_time,
};
use fal::util::proptest::Prop;

fn cfg(name: &str) -> ModelConfig {
    ModelConfig::paper_scale(name).unwrap()
}

#[test]
fn hidden_fraction_bounded_and_monotone_in_compute() {
    Prop::new(300).check(
        "hidden fraction in [0,1], monotone in compute",
        |r| (r.below(1_000_000), r.below(1_000_000)),
        |&(c, m)| {
            let (c, m) = (c as f64 * 1e-5, m as f64 * 1e-5);
            let f = predicted_hidden_fraction(c, m);
            let more = predicted_hidden_fraction(c + 1.0, m);
            (0.0..=1.0).contains(&f) && more >= f
        },
    );
    // Edge cases the generator can't hit: negative compute clamps to 0,
    // zero comm means nothing left to hide.
    assert_eq!(predicted_hidden_fraction(-3.0, 1.0), 0.0);
    assert_eq!(predicted_hidden_fraction(0.0, 0.0), 1.0);
}

#[test]
fn bubble_fraction_bounded_and_monotone() {
    Prop::new(300).check(
        "bubble in [0,1), zero iff one stage, monotone both ways",
        |r| (1 + r.below(64), 1 + r.below(64)),
        |&(t, m)| {
            let f = pipeline_bubble_fraction(t, m);
            (0.0..1.0).contains(&f)
                && (t != 1 || f == 0.0)
                && (t == 1 || f > 0.0)
                && pipeline_bubble_fraction(t + 1, m) >= f
                && pipeline_bubble_fraction(t, m + 1) <= f
        },
    );
}

#[test]
fn step_time_monotone_in_batch() {
    // Doubling the batch must increase every component-total, on both a
    // compute-rich and a comm-rich system.
    for (gpu, link) in [(&RTX_3090, &PCIE_GEN4), (&H200, &NVLINK)] {
        for variant in [Variant::PreLn, Variant::Fal] {
            let c = cfg("774M");
            let mut prev = 0.0;
            for batch in [1usize, 2, 4, 8, 16, 32, 64] {
                let t = train_step_time(&c, variant, gpu, link, 4, batch, true)
                    .total();
                assert!(
                    t > prev,
                    "{} batch {batch}: {t} !> {prev}",
                    variant.name()
                );
                prev = t;
            }
        }
    }
}

#[test]
fn step_time_monotone_in_model_size() {
    // The paper's scale ladder is strictly ordered in predicted step
    // time at fixed (gpu, link, tp, batch).
    let mut prev = 0.0;
    for name in ["774M", "1.5B", "2.5B", "8.3B"] {
        let t = train_step_time(
            &cfg(name), Variant::PreLn, &H200, &NVLINK, 8, 8, true)
        .total();
        assert!(t > prev, "{name}: {t} !> {prev}");
        prev = t;
    }
    // And in depth alone: same width, double the layers.
    let base = cfg("774M");
    let mut deep = base.clone();
    deep.n_layer *= 2;
    let t_base = train_step_time(
        &base, Variant::Fal, &RTX_3090, &PCIE_GEN4, 4, 8, true);
    let t_deep = train_step_time(
        &deep, Variant::Fal, &RTX_3090, &PCIE_GEN4, 4, 8, true);
    assert!(t_deep.total() > 1.8 * t_base.total());
}

#[test]
fn tp_scaling_crossover_comm_eventually_dominates() {
    // Per-device compute shrinks ~1/tp while each all-reduce grows with
    // the ring, so the comm share must rise monotonically with tp and
    // eventually pass 50% on a PCIe-class link.
    let c = cfg("774M");
    let mut prev_share = 0.0;
    let mut crossed = false;
    for tp in [2usize, 4, 8, 16] {
        let st = train_step_time(
            &c, Variant::PreLn, &RTX_3090, &PCIE_GEN4, tp, 8, true);
        let share = st.comm / st.total();
        assert!(share > prev_share, "tp {tp}: {share} !> {prev_share}");
        prev_share = share;
        crossed |= share > 0.5;
    }
    assert!(crossed, "comm never dominated (final share {prev_share:.3})");
    // Compute itself keeps shrinking: the crossover is structural, not
    // an artifact of compute growing.
    let c4 = train_step_time(
        &c, Variant::PreLn, &RTX_3090, &PCIE_GEN4, 4, 8, true);
    let c16 = train_step_time(
        &c, Variant::PreLn, &RTX_3090, &PCIE_GEN4, 16, 8, true);
    assert!(c16.fwd_compute < c4.fwd_compute);
}

#[test]
fn decode_dtyped_f32_never_faster_than_bf16() {
    // Halving the storage bytes can only shorten the memory-bound
    // compute term; comm is activation-typed and must not move.
    Prop::new(100).check(
        "f32 decode >= bf16 decode",
        |r| (1 + r.below(32), 1 + r.below(1024)),
        |&(batch, kv)| {
            let c = cfg("1.5B");
            let f32d = decode_step_time_dtyped(
                &c, Variant::Fal, &RTX_3090, &PCIE_GEN4, 4, batch, kv,
                4.0, 4.0,
            );
            let bf16 = decode_step_time_dtyped(
                &c, Variant::Fal, &RTX_3090, &PCIE_GEN4, 4, batch, kv,
                2.0, 2.0,
            );
            f32d.total() >= bf16.total()
                && f32d.compute > bf16.compute
                && f32d.comm == bf16.comm
        },
    );
}

#[test]
fn fal_comm_strictly_below_preln_at_every_tp() {
    // The paper's Fig 2 inequality, as the cost model prices it: FAL's
    // 1-AR-per-main-block schedule strictly undercuts Pre-LN's 2 at
    // every tensor-parallel degree ≥ 2, on every link.
    for link in [&PCIE_GEN4, &NVLINK] {
        for tp in 2..=16usize {
            let c = cfg("774M");
            let preln = train_step_time(
                &c, Variant::PreLn, &RTX_3090, link, tp, 8, true);
            let fal = train_step_time(
                &c, Variant::Fal, &RTX_3090, link, tp, 8, true);
            assert!(
                fal.comm < preln.comm,
                "tp {tp} on {}: fal {} !< preln {}",
                link.name,
                fal.comm,
                preln.comm
            );
            // Compute is identical — the win is pure comm structure.
            assert!(
                (fal.comm / preln.comm) < 0.62,
                "tp {tp}: ratio {:.3} not near the (L+2)/(2L+2) band",
                fal.comm / preln.comm
            );
        }
    }
    // tp = 1: no interconnect, both zero.
    let c = cfg("774M");
    let solo = train_step_time(
        &c, Variant::Fal, &RTX_3090, &PCIE_GEN4, 1, 8, true);
    assert_eq!(solo.comm, 0.0);
}

#[test]
fn layout_step_time_invariants() {
    // The composite the planner ranks: overlap never loses to serial on
    // the same layout, raw comm is sched-invariant, the bubble matches
    // the closed form, and the memory gauge orders 1f1b under gpipe.
    let c = cfg("774M");
    let grid: Vec<(usize, usize, usize, usize)> = vec![
        (1, 4, 1, 1),
        (1, 2, 2, 2),
        (1, 1, 4, 4),
        (2, 2, 1, 1),
        (4, 1, 1, 1),
        (2, 1, 2, 4),
    ];
    for &(dp, tp, pp, micro) in &grid {
        for variant in [Variant::PreLn, Variant::Fal, Variant::FalPlus] {
            let serial = layout_step_time(
                &c, variant, &RTX_3090, &PCIE_GEN4, dp, tp, pp, micro,
                false, 8,
            );
            let overlap = layout_step_time(
                &c, variant, &RTX_3090, &PCIE_GEN4, dp, tp, pp, micro,
                true, 8,
            );
            assert!(serial.step > 0.0 && serial.compute > 0.0);
            assert_eq!(serial.hidden_fraction, 0.0);
            assert_eq!(serial.raw_comm, overlap.raw_comm);
            assert!(overlap.exposed_comm <= serial.exposed_comm);
            assert!(overlap.step <= serial.step);
            assert!((0.0..=1.0).contains(&overlap.hidden_fraction));
            assert_eq!(
                serial.bubble_fraction,
                pipeline_bubble_fraction(pp, micro)
            );
            if pp == 1 {
                assert_eq!(serial.bubble_fraction, 0.0);
            }
        }
        let gpipe = layout_peak_mem_bytes(&c, tp, pp, micro, 8 / dp, false);
        let ofob = layout_peak_mem_bytes(&c, tp, pp, micro, 8 / dp, true);
        assert!(ofob <= gpipe, "1f1b gauge above gpipe at pp {pp}");
        assert!(gpipe > 0.0);
    }
}
