//! The central numerical claim of the TP coordinator: sharded execution
//! with Rust-owned collectives reproduces the monolithic model exactly
//! (up to f32 reassociation), for both Pre-LN and FAL — and FAL's schedule
//! moves ~half the bytes.
//!
//! Runs on the native CPU backend (default features): the stage kernels and
//! the fused train step are independent implementations of the same math
//! only in the sense of composition — sharded stages + host collectives vs
//! one full-model pass — so agreement here validates the whole schedule.

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::sp_trainer::{Schedule, Trainer};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::costmodel;
use fal::data::{Batch, Corpus, CorpusSpec, Loader};
use fal::runtime::{Backend, ExecCtx, NativeBackend, SchedMode};

fn engine() -> NativeBackend {
    NativeBackend::synthetic()
}

fn batch(engine: &NativeBackend, seed: u64) -> Batch {
    let cfg = engine.manifest().config("tiny").unwrap();
    let corpus = Corpus::generate(
        CorpusSpec::for_vocab(cfg.vocab_size), 20_000, 3);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, seed);
    loader.fixed_batch(seed)
}

#[test]
fn tp_forward_matches_single_process_preln() {
    let eng = engine();
    let b = batch(&eng, 1);
    let tc = TrainConfig::default();
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::PreLn, 2, PCIE_GEN4, tc).unwrap();
    let tp_loss = tp.forward_loss(&b).unwrap();
    let mut sp = Trainer::new(&eng, "tiny", "preln", Schedule::Constant).unwrap();
    let sp_loss = sp.eval_loss(&b).unwrap();
    let rel = ((tp_loss - sp_loss) / sp_loss).abs();
    assert!(rel < 1e-3, "tp {tp_loss} vs sp {sp_loss} (rel {rel})");
}

#[test]
fn tp_forward_matches_single_process_fal() {
    let eng = engine();
    let b = batch(&eng, 2);
    let tc = TrainConfig::default();
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::Fal, 2, PCIE_GEN4, tc).unwrap();
    let tp_loss = tp.forward_loss(&b).unwrap();
    let mut sp = Trainer::new(&eng, "tiny", "fal", Schedule::Constant).unwrap();
    let sp_loss = sp.eval_loss(&b).unwrap();
    let rel = ((tp_loss - sp_loss) / sp_loss).abs();
    assert!(rel < 1e-3, "tp {tp_loss} vs sp {sp_loss} (rel {rel})");
}

#[test]
fn tp_forward_matches_single_process_falplus() {
    // FAL+ TP: prep block reuses the raw MHA out, every main block
    // re-normalizes it with its own LNf_i — the sharded schedule must
    // agree with the fused falplus train step.
    let eng = engine();
    let b = batch(&eng, 2);
    let tc = TrainConfig::default();
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::FalPlus, 2, PCIE_GEN4, tc)
            .unwrap();
    let tp_loss = tp.forward_loss(&b).unwrap();
    let mut sp =
        Trainer::new(&eng, "tiny", "falplus", Schedule::Constant).unwrap();
    let sp_loss = sp.eval_loss(&b).unwrap();
    let rel = ((tp_loss - sp_loss) / sp_loss).abs();
    assert!(rel < 1e-3, "tp {tp_loss} vs sp {sp_loss} (rel {rel})");
}

#[test]
fn tp_training_trajectory_matches_fused_step() {
    // Five full steps on a fixed batch: the Rust TP trainer (sharded bwd +
    // host AdamW) must track the fused train step closely.
    let eng = engine();
    let b = batch(&eng, 3);
    let tc = TrainConfig::default();
    for (variant, tag) in [
        (Variant::PreLn, "preln"),
        (Variant::Fal, "fal"),
        (Variant::FalPlus, "falplus"),
    ] {
        let mut tp =
            TpTrainer::new(&eng, "tiny", variant, 2, PCIE_GEN4, tc).unwrap();
        let mut sp = Trainer::new(&eng, "tiny", tag, Schedule::Constant).unwrap();
        let mut max_rel: f64 = 0.0;
        for _ in 0..5 {
            let (tp_loss, tp_gnorm) = tp.train_step(&b).unwrap();
            let out = sp.train_step(&b).unwrap();
            let rel = ((tp_loss - out.loss) / out.loss).abs() as f64;
            max_rel = max_rel.max(rel);
            assert!(tp_gnorm.is_finite());
            assert!(
                rel < 5e-3,
                "{tag}: step loss diverged tp {tp_loss} sp {} (rel {rel})",
                out.loss
            );
        }
        // Training must actually learn (fixed batch -> loss falls).
        let (last, _) = tp.train_step(&b).unwrap();
        assert!(last.is_finite(), "{tag}: loss not finite after 6 steps");
        println!("{tag}: max relative loss deviation {max_rel:.2e}");
    }
}

#[test]
fn fal_tp_halves_communication_volume() {
    let eng = engine();
    let b = batch(&eng, 4);
    let tc = TrainConfig::default();
    let mut run = |variant| {
        let mut tp =
            TpTrainer::new(&eng, "tiny", variant, 2, PCIE_GEN4, tc).unwrap();
        tp.train_step(&b).unwrap();
        tp.ledger.stats()
    };
    let preln = run(Variant::PreLn);
    let fal = run(Variant::Fal);
    let ratio = fal.allreduce_bytes / preln.allreduce_bytes;
    // tiny has 4 layers: preln = 4L = 16 ARs; fal = 2 + (L-1) fwd + mirrored
    // bwd ≈ (L+1)/2L of the volume = 0.625 at L=4 (approaches 0.5 as L grows).
    assert!(
        (0.5..0.72).contains(&ratio),
        "volume ratio {ratio:.3} (preln {} fal {})",
        preln.allreduce_bytes,
        fal.allreduce_bytes
    );
    assert!(fal.modeled_secs < preln.modeled_secs);
}

#[test]
fn ledger_matches_cost_model_volumes() {
    // Acceptance: the CommLedger byte counts from real sharded execution
    // must equal the analytic cost model's predicted volumes. The ledger
    // counts host f32 bytes, the model counts ELEM(=2)-byte mixed-precision
    // activations, so volumes are compared after scaling by 4/ELEM. FAL
    // carries one extra documented all-reduce (the dfa aggregate in block
    // 1's backward) on top of the model's 2*(L+1) activation all-reduces.
    let eng = engine();
    let b = batch(&eng, 5);
    let cfg = eng.manifest().config("tiny").unwrap().clone();
    let act4 = (4 * cfg.seq_len * cfg.d_model * 4) as f64; // [B,S,D] f32
    for tp in [2usize, 4] {
        for variant in [Variant::PreLn, Variant::Fal] {
            let mut t = TpTrainer::new(
                &eng, "tiny", variant, tp, PCIE_GEN4, TrainConfig::default(),
            )
            .unwrap();
            t.train_step(&b).unwrap();
            let s = t.ledger.stats();
            let fwd = costmodel::fwd_allreduces(variant, cfg.n_layer) as u64;
            let extra = match variant {
                Variant::Fal => 1, // dfa all-reduce, bwd block 1
                _ => 0,
            };
            let want_ars = 2 * fwd + extra;
            assert_eq!(
                s.allreduces, want_ars,
                "{} tp{tp}: {} ARs, want {want_ars}",
                variant.name(), s.allreduces
            );
            let want_bytes = want_ars as f64 * act4;
            assert!(
                (s.allreduce_bytes - want_bytes).abs() < 1e-6,
                "{} tp{tp}: {} AR bytes, want {want_bytes}",
                variant.name(), s.allreduce_bytes
            );
            // Cross-check against the cost model's step volume.
            let model =
                costmodel::step_comm_bytes(&cfg, variant, 4) * 4.0
                    / costmodel::ELEM;
            assert!(
                (s.allreduce_bytes - extra as f64 * act4 - model).abs() < 1e-6,
                "{} tp{tp}: ledger {} vs cost model {model}",
                variant.name(), s.allreduce_bytes
            );
        }
    }
}

#[test]
fn tp_loss_decreases_over_steps() {
    let eng = engine();
    let b = batch(&eng, 5);
    let tc = TrainConfig { lr: 3e-3, ..Default::default() };
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::Fal, 2, PCIE_GEN4, tc).unwrap();
    let (first, _) = tp.train_step(&b).unwrap();
    let mut last = first;
    for _ in 0..9 {
        last = tp.train_step(&b).unwrap().0;
    }
    assert!(
        last < first - 0.3,
        "TP training failed to learn: {first} -> {last}"
    );
}

/// StageGraph acceptance: the rank-parallel schedule (`--sched graph`)
/// and the comm-overlapping schedule (`--sched overlap`, all-reduces as
/// eager-value comm nodes) must both reproduce the historical serial rank
/// loop (`--sched serial`) **0-ulp** — losses and every updated parameter
/// — at threads {1, 2, 4, 7}, for both the Pre-LN and the fused FAL
/// schedules. The CommLedger byte accounting is also schedule-invariant:
/// same collective count, payload bytes and (same-sized payloads, so
/// order-insensitive) modeled link time in all three modes.
#[test]
fn overlap_graph_serial_three_way_zero_ulp() {
    let run = |variant: Variant, threads: usize, sched: SchedMode| {
        let eng = NativeBackend::synthetic_with_ctx(
            ExecCtx::new(threads).with_sched(sched),
        );
        let b = batch(&eng, 9);
        let mut tp = TpTrainer::new(
            &eng, "tiny", variant, 2, PCIE_GEN4, TrainConfig::default(),
        )
        .unwrap();
        let mut losses = Vec::new();
        for _ in 0..2 {
            losses.push(tp.train_step(&b).unwrap().0.to_bits());
        }
        let params: Vec<Vec<u32>> = tp
            .params
            .to_flat()
            .iter()
            .map(|t| t.data.iter().map(|v| v.to_bits()).collect())
            .collect();
        (losses, params, tp.ledger.stats())
    };
    for variant in [Variant::PreLn, Variant::Fal, Variant::FalPlus] {
        for threads in [1usize, 2, 4, 7] {
            let (loss_s, params_s, stats_s) =
                run(variant, threads, SchedMode::Serial);
            for sched in [SchedMode::Graph, SchedMode::Overlap] {
                let (loss, params, stats) = run(variant, threads, sched);
                assert_eq!(
                    loss_s, loss,
                    "{variant:?} t{threads} {sched:?}: losses diverged"
                );
                assert_eq!(
                    params_s, params,
                    "{variant:?} t{threads} {sched:?}: params not 0-ulp"
                );
                // Byte-accounting invariance across schedules.
                assert_eq!(stats.allreduces, stats_s.allreduces);
                assert_eq!(stats.broadcasts, stats_s.broadcasts);
                assert_eq!(stats.allreduce_bytes, stats_s.allreduce_bytes);
                assert_eq!(stats.broadcast_bytes, stats_s.broadcast_bytes);
                let rel = (stats.modeled_secs - stats_s.modeled_secs).abs()
                    / stats_s.modeled_secs.max(1e-12);
                assert!(
                    rel < 1e-9,
                    "{variant:?} t{threads} {sched:?}: modeled comm drifted \
                     ({} vs {})",
                    stats.modeled_secs,
                    stats_s.modeled_secs
                );
            }
        }
    }
}

/// The reuse-layer ablation rides the fused native train step (tag
/// `falplus_k2`): its StageGraph execution (MHA ∥ MLP forks, degenerate
/// chains) must also be 0-ulp identical across serial/graph/overlap at
/// every thread count — losses and the full updated parameter state.
#[test]
fn falplus_k2_three_way_zero_ulp() {
    let run = |threads: usize, sched: SchedMode| {
        let eng = NativeBackend::synthetic_with_ctx(
            ExecCtx::new(threads).with_sched(sched),
        );
        let b = batch(&eng, 11);
        let mut t =
            Trainer::new(&eng, "tiny", "falplus_k2", Schedule::Constant)
                .unwrap();
        let mut losses = Vec::new();
        for _ in 0..2 {
            losses.push(t.train_step(&b).unwrap().loss.to_bits());
        }
        let params: Vec<Vec<u32>> = t
            .params()
            .iter()
            .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
            .collect();
        (losses, params)
    };
    for threads in [1usize, 2, 4, 7] {
        let (loss_s, params_s) = run(threads, SchedMode::Serial);
        for sched in [SchedMode::Graph, SchedMode::Overlap] {
            let (loss, params) = run(threads, sched);
            assert_eq!(
                loss_s, loss,
                "falplus_k2 t{threads} {sched:?}: losses diverged"
            );
            assert_eq!(
                params_s, params,
                "falplus_k2 t{threads} {sched:?}: params not 0-ulp"
            );
        }
    }
}

#[test]
fn tp_breakdown_buckets_populated() {
    let eng = engine();
    let b = batch(&eng, 6);
    let mut tp = TpTrainer::new(
        &eng, "tiny", Variant::PreLn, 2, PCIE_GEN4, TrainConfig::default(),
    )
    .unwrap();
    tp.train_step(&b).unwrap();
    for bucket in ["fwd", "bwd", "opt"] {
        assert!(
            tp.breakdown.get(bucket) > 0.0,
            "missing breakdown bucket {bucket}"
        );
    }
}
