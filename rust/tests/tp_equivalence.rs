//! The central numerical claim of the TP coordinator: sharded execution
//! with Rust-owned collectives reproduces the monolithic model exactly
//! (up to f32 reassociation), for both Pre-LN and FAL — and FAL's schedule
//! moves ~half the bytes.

use std::path::Path;

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::sp_trainer::{Schedule, Trainer};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::data::{Batch, Corpus, CorpusSpec, Loader};
use fal::runtime::Engine;

fn engine() -> Engine {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::new(&dir).expect("run `make artifacts` before cargo test")
}

fn batch(engine: &Engine, seed: u64) -> Batch {
    let cfg = engine.manifest.config("tiny").unwrap();
    let corpus = Corpus::generate(
        CorpusSpec::for_vocab(cfg.vocab_size), 20_000, 3);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, seed);
    loader.fixed_batch(seed)
}

#[test]
fn tp_forward_matches_single_process_preln() {
    let eng = engine();
    let b = batch(&eng, 1);
    let tc = TrainConfig::default();
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::PreLn, 2, PCIE_GEN4, tc).unwrap();
    let tp_loss = tp.forward_loss(&b).unwrap();
    let mut sp = Trainer::new(&eng, "tiny", "preln", Schedule::Constant).unwrap();
    let sp_loss = sp.eval_loss(&b).unwrap();
    let rel = ((tp_loss - sp_loss) / sp_loss).abs();
    assert!(rel < 1e-3, "tp {tp_loss} vs sp {sp_loss} (rel {rel})");
}

#[test]
fn tp_forward_matches_single_process_fal() {
    let eng = engine();
    let b = batch(&eng, 2);
    let tc = TrainConfig::default();
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::Fal, 2, PCIE_GEN4, tc).unwrap();
    let tp_loss = tp.forward_loss(&b).unwrap();
    let mut sp = Trainer::new(&eng, "tiny", "fal", Schedule::Constant).unwrap();
    let sp_loss = sp.eval_loss(&b).unwrap();
    let rel = ((tp_loss - sp_loss) / sp_loss).abs();
    assert!(rel < 1e-3, "tp {tp_loss} vs sp {sp_loss} (rel {rel})");
}

#[test]
fn tp_training_trajectory_matches_fused_step() {
    // Five full steps on a fixed batch: the Rust TP trainer (sharded bwd +
    // host AdamW) must track the fused single-HLO train step closely.
    let eng = engine();
    let b = batch(&eng, 3);
    let tc = TrainConfig::default();
    for (variant, tag) in [(Variant::PreLn, "preln"), (Variant::Fal, "fal")] {
        let mut tp =
            TpTrainer::new(&eng, "tiny", variant, 2, PCIE_GEN4, tc).unwrap();
        let mut sp = Trainer::new(&eng, "tiny", tag, Schedule::Constant).unwrap();
        let mut max_rel: f64 = 0.0;
        for _ in 0..5 {
            let (tp_loss, tp_gnorm) = tp.train_step(&b).unwrap();
            let out = sp.train_step(&b).unwrap();
            let rel = ((tp_loss - out.loss) / out.loss).abs() as f64;
            max_rel = max_rel.max(rel);
            assert!(tp_gnorm.is_finite());
            assert!(
                rel < 5e-3,
                "{tag}: step loss diverged tp {tp_loss} sp {} (rel {rel})",
                out.loss
            );
        }
        // Training must actually learn (fixed batch -> loss falls).
        let (last, _) = tp.train_step(&b).unwrap();
        assert!(
            last < tp.breakdown.total() as f32 + 10.0,
            "sanity: loss finite"
        );
        println!("{tag}: max relative loss deviation {max_rel:.2e}");
    }
}

#[test]
fn fal_tp_halves_communication_volume() {
    let eng = engine();
    let b = batch(&eng, 4);
    let tc = TrainConfig::default();
    let mut run = |variant| {
        let mut tp =
            TpTrainer::new(&eng, "tiny", variant, 2, PCIE_GEN4, tc).unwrap();
        tp.train_step(&b).unwrap();
        tp.ledger.stats()
    };
    let preln = run(Variant::PreLn);
    let fal = run(Variant::Fal);
    let ratio = fal.allreduce_bytes / preln.allreduce_bytes;
    // tiny has 4 layers: preln = 4L = 16 ARs; fal = 2 + (L-1) fwd + mirrored
    // bwd ≈ (L+1)/2L of the volume = 0.625 at L=4 (approaches 0.5 as L grows).
    assert!(
        (0.5..0.72).contains(&ratio),
        "volume ratio {ratio:.3} (preln {} fal {})",
        preln.allreduce_bytes,
        fal.allreduce_bytes
    );
    assert!(fal.modeled_secs < preln.modeled_secs);
}

#[test]
fn tp_loss_decreases_over_steps() {
    let eng = engine();
    let b = batch(&eng, 5);
    let tc = TrainConfig { lr: 3e-3, ..Default::default() };
    let mut tp =
        TpTrainer::new(&eng, "tiny", Variant::Fal, 2, PCIE_GEN4, tc).unwrap();
    let (first, _) = tp.train_step(&b).unwrap();
    let mut last = first;
    for _ in 0..9 {
        last = tp.train_step(&b).unwrap().0;
    }
    assert!(
        last < first - 0.3,
        "TP training failed to learn: {first} -> {last}"
    );
}

#[test]
fn tp_breakdown_buckets_populated() {
    let eng = engine();
    let b = batch(&eng, 6);
    let mut tp = TpTrainer::new(
        &eng, "tiny", Variant::PreLn, 2, PCIE_GEN4, TrainConfig::default(),
    )
    .unwrap();
    tp.train_step(&b).unwrap();
    for bucket in ["fwd", "bwd", "opt"] {
        assert!(
            tp.breakdown.get(bucket) > 0.0,
            "missing breakdown bucket {bucket}"
        );
    }
}
