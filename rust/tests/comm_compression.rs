//! Integration coverage for the gradient-compression baselines' *public*
//! APIs — the surface the Fig 7 harness consumes: the [`Compressor`]
//! trait (`compress`/`decompress`/`ratio`), the [`Payload`] wire formats,
//! [`Qsgd`], [`PowerSgd`], and the [`ErrorFeedback`] wrapper. The
//! in-module unit tests own the math properties (unbiasedness, cell
//! bounds, orthonormality); these tests pin the contracts a caller
//! outside the crate relies on: wire-size formulas, shape round-trips,
//! seed determinism, trait-object usability, and the EF invariants.

use fal::comm::error_feedback::{transmit_dense, ErrorFeedback};
use fal::comm::powersgd::PowerSgd;
use fal::comm::qsgd::Qsgd;
use fal::comm::{Compressor, DenseCodec, Payload};
use fal::tensor::HostTensor;
use fal::util::rng::Rng;

#[test]
fn qsgd_wire_format_and_ratio() {
    // n=100, bucket=32: 4 buckets -> 4 scale f32s + one i8 per element.
    let mut rng = Rng::new(21);
    let g = HostTensor::randn(&[100], 0.5, &mut rng);
    let mut c = Qsgd::new(4, 32, 0);
    let (p, wire) = c.compress(&g);
    assert_eq!(wire, 4 * 4 + 100);
    assert!(c.ratio(100, wire) > 3.0);
    let Payload::Quantized { scales, levels, bucket } = &p else {
        panic!("qsgd must emit Payload::Quantized");
    };
    assert_eq!(*bucket, 32);
    assert_eq!(scales.len(), 4);
    assert_eq!(levels.len(), 100);
    // Levels live on the grid: |lv| <= positive level count.
    assert!(levels.iter().all(|&l| l.abs() <= 4));
    let d = c.decompress(&p, &[100]);
    assert_eq!(d.shape, vec![100]);
    // Reconstruction never exceeds its bucket's max-abs scale.
    let gmax = g.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    assert!(d.data.iter().all(|v| v.abs() <= gmax + 1e-6));
}

#[test]
fn qsgd_same_seed_same_bits() {
    let mut rng = Rng::new(22);
    let g = HostTensor::randn(&[257], 1.0, &mut rng);
    let enc = |seed: u64| {
        let mut c = Qsgd::new(8, 64, seed);
        let (p, _) = c.compress(&g);
        c.decompress(&p, &[257]).data
    };
    let (a, b) = (enc(42), enc(42));
    assert!(a
        .iter()
        .zip(&b)
        .all(|(x, y)| x.to_bits() == y.to_bits()));
    // And a different seed actually changes the stochastic rounding.
    let c = enc(43);
    assert!(a.iter().zip(&c).any(|(x, y)| x != y));
}

#[test]
fn powersgd_full_rank_reconstructs_any_matrix() {
    // r = min(n, m): P spans the full column space, so P Q'^T = M up to
    // f32 rounding — the exactness limit of the codec.
    let mut rng = Rng::new(23);
    let mut g = HostTensor::zeros(&[12, 7]);
    rng.fill_normal(&mut g.data, 1.0);
    let mut c = PowerSgd::new(7, 0);
    let (p, wire) = c.compress(&g);
    assert_eq!(wire, (12 + 7) * 7 * 4);
    let d = c.decompress(&p, &[12, 7]);
    assert!(d.rel_err(&g) < 1e-4, "rel err {}", d.rel_err(&g));
}

#[test]
fn powersgd_flattens_higher_dims_and_passes_vectors_dense() {
    // A [4, 3, 2] gradient compresses as a 4 x 6 matrix...
    let mut rng = Rng::new(24);
    let g = HostTensor::randn(&[4, 3, 2], 1.0, &mut rng);
    let mut c = PowerSgd::new(2, 0);
    let (p, wire) = c.compress(&g);
    assert_eq!(wire, (4 + 6) * 2 * 4);
    let Payload::LowRank { rows, cols, .. } = &p else {
        panic!("matrix-shaped gradient must emit Payload::LowRank");
    };
    assert_eq!((*rows, *cols), (4, 6));
    // ...and decompresses back to the original 3-D shape.
    assert_eq!(c.decompress(&p, &[4, 3, 2]).shape, vec![4, 3, 2]);
    // 1-D gradients bypass the factorization entirely.
    let v = HostTensor::from_vec(&[6], vec![1., 2., 3., 4., 5., 6.]);
    let (pv, wv) = c.compress(&v);
    assert_eq!(wv, v.size_bytes());
    assert!(matches!(pv, Payload::Dense(_)));
    assert_eq!(c.decompress(&pv, &[6]), v);
}

#[test]
fn powersgd_rank_is_capped_by_matrix_dims() {
    // rank 16 on an 8 x 4 gradient silently clamps to 4 — the wire size
    // proves it, and reconstruction is the full-rank (near-exact) one.
    let mut rng = Rng::new(25);
    let mut g = HostTensor::zeros(&[8, 4]);
    rng.fill_normal(&mut g.data, 1.0);
    let mut c = PowerSgd::new(16, 0);
    let (p, wire) = c.compress(&g);
    assert_eq!(wire, (8 + 4) * 4 * 4);
    assert!(c.decompress(&p, &[8, 4]).rel_err(&g) < 1e-4);
}

#[test]
fn error_feedback_around_dense_is_the_identity() {
    // EF's residual of a lossless codec is identically zero: transmit
    // returns the gradient bit-for-bit and the diagnostic norm stays 0.
    let mut ef = ErrorFeedback::new(DenseCodec);
    let mut rng = Rng::new(26);
    for step in 0..5 {
        let g = HostTensor::randn(&[33], 1.0, &mut rng);
        let (d, wire) = ef.transmit("w", &g);
        assert_eq!(wire, g.size_bytes(), "step {step}");
        assert!(d
            .data
            .iter()
            .zip(&g.data)
            .all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(ef.residual_norm(), 0.0);
    }
}

#[test]
fn error_feedback_sum_of_transmissions_tracks_the_signal() {
    // The defining EF identity: sum_t decode_t = T*g + e_0 - e_T, so with
    // a bounded residual the accumulated reconstruction tracks T*g.
    let g = HostTensor::from_vec(&[4], vec![0.2, -0.4, 0.6, -0.8]);
    let mut ef = ErrorFeedback::new(Qsgd::new(3, 16, 11));
    let mut acc = HostTensor::zeros(&[4]);
    let t = 100;
    for _ in 0..t {
        let (d, _) = ef.transmit("w", &g);
        acc.add_assign(&d);
    }
    for (a, x) in acc.data.iter().zip(&g.data) {
        let want = x * t as f32;
        assert!((a - want).abs() < 0.5, "accumulated {a} vs {want}");
    }
    assert!(ef.residual_norm() < 1.0, "{}", ef.residual_norm());
}

#[test]
fn error_feedback_with_powersgd_stays_bounded() {
    // PowerSGD requires EF; over repeated steps on a varying full-rank
    // gradient the residual must not blow up and every transmission
    // keeps the low-rank wire cost.
    let mut rng = Rng::new(27);
    let mut ef = ErrorFeedback::new(PowerSgd::new(2, 1));
    let n = 16 * 12;
    for _ in 0..30 {
        let g = HostTensor::randn(&[16, 12], 1.0, &mut rng);
        let (d, wire) = ef.transmit("w", &g);
        assert_eq!(d.shape, vec![16, 12]);
        assert_eq!(wire, (16 + 12) * 2 * 4);
        assert!(wire < n * 4);
    }
    let per_elem = ef.residual_norm() / (n as f64).sqrt();
    assert!(per_elem < 6.0, "residual per element {per_elem}");
}

#[test]
fn transmit_dense_is_the_uniform_baseline_path() {
    let g = HostTensor::from_vec(&[3], vec![1.0, -1.0, 0.5]);
    let (d, wire) = transmit_dense(&g);
    assert_eq!(d, g);
    assert_eq!(wire, 12);
}

#[test]
fn codecs_are_usable_as_trait_objects() {
    // The Fig 7 harness iterates Box<dyn Compressor>; every codec must
    // round-trip shape-correctly through the trait and undercut (or
    // match) the dense wire size.
    let mut rng = Rng::new(28);
    let g = HostTensor::randn(&[16, 16], 1.0, &mut rng);
    let mut codecs: Vec<Box<dyn Compressor>> = vec![
        Box::new(DenseCodec),
        Box::new(Qsgd::new(4, 64, 9)),
        Box::new(PowerSgd::new(4, 9)),
    ];
    let mut names = Vec::new();
    for c in codecs.iter_mut() {
        let (p, wire) = c.compress(&g);
        assert!(wire <= g.size_bytes(), "{}: wire {wire}", c.name());
        let d = c.decompress(&p, &[16, 16]);
        assert_eq!(d.shape, g.shape, "{}", c.name());
        assert!(c.ratio(256, wire) >= 1.0 - 1e-9);
        names.push(c.name());
    }
    assert_eq!(names, vec!["dense", "qsgd", "powersgd"]);
}
