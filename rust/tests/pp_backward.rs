//! Differential harness for the executed pipeline backward.
//!
//! The pipeline trainer schedules one full training step — forward
//! staircase, reversed P2P gradient sends, backward staircase — as a
//! single StageGraph under two linearizations (`--pp-sched gpipe|1f1b`)
//! and three scheduler modes (`--sched serial|graph|overlap`). This
//! harness pins the correctness story from three independent directions:
//!
//! 1. **Finite differences**: the executed pipeline's gradients on every
//!    stage's parameters (plus the shared embedding/head set) match a
//!    central-difference probe of the objective.
//! 2. **Bitwise differential**: losses, gradients, gnorm, and post-step
//!    parameters are 0-ulp identical to the monolithic single-device
//!    reference loop under every (threads × mode × pp-sched) combination
//!    — including randomly drawn (stages × micro × threads × mode) grids.
//! 3. **Schedule structure**: replaying the captured step-graph spec with
//!    atomic done-flags proves no cell starts before its declared deps at
//!    any worker count, and the stash table's live counts show 1F1B
//!    bounding activation memory to the pipeline depth with last-reader
//!    release draining the table by step end.

use std::sync::atomic::{AtomicBool, Ordering};

use fal::config::PCIE_GEN4;
use fal::coordinator::dp_pp::{PpSched, PpTrainer};
use fal::coordinator::topology::NamedParams;
use fal::data::{Batch, Corpus, CorpusSpec, Loader};
use fal::runtime::{
    Backend, ExecCtx, GraphSpec, NativeBackend, SchedMode, StageGraph,
};
use fal::util::proptest::Prop;

const MODES: [SchedMode; 3] =
    [SchedMode::Serial, SchedMode::Graph, SchedMode::Overlap];
const SCHEDS: [PpSched; 2] = [PpSched::GPipe, PpSched::OneFOneB];
const THREADS: [usize; 4] = [1, 2, 4, 7];

fn batch(engine: &NativeBackend, seed: u64) -> Batch {
    let cfg = engine.manifest().config("tiny").unwrap();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 20_000, 3);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, seed);
    loader.fixed_batch(seed)
}

fn trainer<'e>(
    eng: &'e NativeBackend,
    stages: usize,
    micro: usize,
    threads: usize,
    mode: SchedMode,
    sched: PpSched,
) -> PpTrainer<'e, NativeBackend> {
    let mut t = PpTrainer::new(eng, "tiny", stages, micro, PCIE_GEN4).unwrap();
    t.ctx = ExecCtx::new(threads).with_sched(mode);
    t.pp_sched = sched;
    t.comm_sim_scale = 2.0;
    t
}

/// Bitwise equality over two named tensor sets (params or grads).
fn named_identical(a: &NamedParams, b: &NamedParams) -> bool {
    a.order == b.order
        && a.order.iter().all(|n| {
            let (x, y) = (&a.by_name[n], &b.by_name[n]);
            x.data.len() == y.data.len()
                && x.data
                    .iter()
                    .zip(&y.data)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

fn assert_named_identical(a: &NamedParams, b: &NamedParams, what: &str) {
    assert_eq!(a.order, b.order, "{what}: name sets differ");
    for n in &a.order {
        let (x, y) = (&a.by_name[n], &b.by_name[n]);
        assert_eq!(x.data.len(), y.data.len(), "{what}: {n} length");
        for i in 0..x.data.len() {
            assert!(
                x.data[i].to_bits() == y.data[i].to_bits(),
                "{what}: {n}[{i}] = {:e} vs {:e}",
                x.data[i],
                y.data[i]
            );
        }
    }
}

/// Finite-difference probes on every stage's parameters: the executed
/// pipeline gradient at the largest-|g| coordinate of each probed tensor
/// must match a central difference of the objective (the mean of
/// per-micro-batch mean losses — exactly what the 1/m-scaled accumulated
/// gradients differentiate).
#[test]
fn fd_gradients_every_stage() {
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 31);
    // 2 stages × 2 layers: blocks.{0,1} live on device 0, blocks.{2,3}
    // on device 1; embeddings enter on device 0, the head on device 1.
    let mut t = trainer(&eng, 2, 2, 2, SchedMode::Graph, PpSched::GPipe);
    let st = t.compute_grads(&b).unwrap();
    let probes = [
        "blocks.0.wq",
        "blocks.0.w1",
        "blocks.0.ln1_g",
        "blocks.1.wo",
        "blocks.2.w2",
        "blocks.2.ln2_b",
        "blocks.3.wv",
        "blocks.3.b1",
        "wte",
        "wpe",
        "lnF_g",
        "lnF_b",
    ];
    for name in probes {
        let g = st.grads.by_name.get(name).unwrap();
        let (idx, &gv) = g
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .unwrap();
        let analytic = gv as f64;
        let eps = 1e-2f32;
        let orig = t.params.by_name.get(name).unwrap().data[idx];
        t.params.by_name.get_mut(name).unwrap().data[idx] = orig + eps;
        let up = t.compute_grads(&b).unwrap().objective;
        t.params.by_name.get_mut(name).unwrap().data[idx] = orig - eps;
        let dn = t.compute_grads(&b).unwrap().objective;
        t.params.by_name.get_mut(name).unwrap().data[idx] = orig;
        let fd = (up - dn) / (2.0 * eps as f64);
        // f32 kernels under an f64 probe: generous relative band plus an
        // absolute floor for coordinates near the noise level.
        let tol = 0.08 * fd.abs().max(analytic.abs()) + 3e-4;
        assert!(
            (fd - analytic).abs() <= tol,
            "{name}[{idx}]: fd {fd:.6e} vs pipeline grad {analytic:.6e} \
             (tol {tol:.2e})"
        );
    }
}

/// The executed pipeline is 0-ulp identical to the monolithic
/// single-device loop on loss, objective, every gradient, the gradient
/// norm, and the post-AdamW parameters — for both pp schedules, all
/// three scheduler modes, at every thread count. (The reference is
/// recomputed per thread count: the partition knob legitimately changes
/// reduction bits; schedules and modes must not.)
#[test]
fn pipeline_matches_monolithic_bitwise_everywhere() {
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 32);
    for &threads in &THREADS {
        let mut rt =
            trainer(&eng, 2, 2, threads, SchedMode::Serial, PpSched::GPipe);
        let rst = rt.reference_grads(&b).unwrap();
        let (rloss, rgnorm) = rt.reference_step(&b).unwrap();
        for mode in MODES {
            for sched in SCHEDS {
                let what = format!("t{threads} {mode:?} {}", sched.name());
                let mut t = trainer(&eng, 2, 2, threads, mode, sched);
                let st = t.compute_grads(&b).unwrap();
                assert_eq!(
                    st.loss.to_bits(),
                    rst.loss.to_bits(),
                    "{what}: loss diverged"
                );
                assert_eq!(
                    st.objective.to_bits(),
                    rst.objective.to_bits(),
                    "{what}: objective diverged"
                );
                assert_named_identical(
                    &st.grads,
                    &rst.grads,
                    &format!("{what} grads"),
                );
                let (loss, gnorm) = t.train_step(&b).unwrap();
                assert_eq!(
                    loss.to_bits(),
                    rloss.to_bits(),
                    "{what}: step loss diverged"
                );
                assert_eq!(
                    gnorm.to_bits(),
                    rgnorm.to_bits(),
                    "{what}: gnorm diverged"
                );
                assert_named_identical(
                    &t.params,
                    &rt.params,
                    &format!("{what} post-step params"),
                );
            }
        }
    }
}

/// Random (stages × micro × threads × mode) grids: gpipe ≡ 1f1b ≡
/// monolithic, 0-ulp, and the measured peak live-stash count never
/// exceeds the schedule's prediction (for 1F1B: the pipeline depth).
#[test]
fn random_grids_gpipe_1f1b_monolithic_agree() {
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 33);
    Prop::new(10).check(
        "gpipe/1f1b/monolithic 0-ulp on random pipeline grids",
        |r| vec![r.below(3), r.below(3), r.below(4), r.below(3)],
        |raw| {
            let get = |i: usize| raw.get(i).copied().unwrap_or(0);
            // tiny has 4 layers and pp bundles at b ∈ {4, 2, 1}.
            let stages = 1usize << (get(0) % 3);
            let micro = 1usize << (get(1) % 3);
            let threads = THREADS[get(2) % THREADS.len()];
            let mode = MODES[get(3) % MODES.len()];
            let mut rt = trainer(
                &eng,
                stages,
                micro,
                threads,
                SchedMode::Serial,
                PpSched::GPipe,
            );
            let r = rt.reference_grads(&b).unwrap();
            SCHEDS.iter().all(|&sched| {
                let mut t = trainer(&eng, stages, micro, threads, mode, sched);
                let st = t.compute_grads(&b).unwrap();
                let peak =
                    t.stash_peaks().into_iter().max().unwrap_or(0);
                st.loss.to_bits() == r.loss.to_bits()
                    && st.objective.to_bits() == r.objective.to_bits()
                    && named_identical(&st.grads, &r.grads)
                    && t.stash_len() == 0
                    && peak <= t.predicted_peak_stash()
                    && (sched != PpSched::OneFOneB
                        || peak <= micro.min(stages))
            })
        },
    );
}

/// Replay the captured step-graph spec with atomic done-flags: under the
/// concurrent scheduler modes at several worker counts, no node may start
/// before every declared data *and* ordering dependency has finished.
fn replay_spec_with_flags(spec: &GraphSpec, threads: usize, mode: SchedMode) {
    let n = spec.nodes.len();
    let flags: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let fr = &flags;
    let mut g: StageGraph<'_, usize> = StageGraph::new();
    for (i, node) in spec.nodes.iter().enumerate() {
        let mut all: Vec<usize> = node.deps.clone();
        all.extend(node.ordering_deps.iter().copied());
        let label = node.label.clone();
        let run = move |_: &ExecCtx, _j: &fal::runtime::Joined<'_, usize>| {
            for &d in &all {
                assert!(
                    fr[d].load(Ordering::SeqCst),
                    "node {i} ({label}) started before dep {d} finished \
                     ({threads} threads, {mode:?})"
                );
            }
            fr[i].store(true, Ordering::SeqCst);
            i
        };
        if let Some(sim) = node.comm_sim_secs {
            g.comm_node_with_ordering(
                node.label.clone(),
                &node.deps,
                &node.ordering_deps,
                sim,
                run,
            );
        } else {
            g.node_with_ordering(
                node.label.clone(),
                &node.deps,
                &node.ordering_deps,
                run,
            );
        }
    }
    let out = g.run(&ExecCtx::new(threads).with_sched(mode));
    assert_eq!(out.len(), n);
    assert!(flags.iter().all(|f| f.load(Ordering::SeqCst)));
}

#[test]
fn no_cell_starts_before_its_dependencies() {
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 34);
    for sched in SCHEDS {
        let mut t = PpTrainer::new(&eng, "tiny", 2, 2, PCIE_GEN4).unwrap();
        t.pp_sched = sched;
        t.comm_sim_scale = 1.0;
        let (_name, spec, _trace) = t.captured_step_graph(&b).unwrap();
        for threads in [2usize, 4, 7] {
            for mode in [SchedMode::Graph, SchedMode::Overlap] {
                replay_spec_with_flags(&spec, threads, mode);
            }
        }
    }
}

/// Last-reader release drains the stash table by step end in every mode,
/// and the per-device peaks realize the schedule's memory claim: GPipe
/// keeps all `m` stashes live per device, 1F1B caps device `s` at
/// `min(m, t−s)`.
#[test]
fn stash_table_drains_and_peaks_follow_the_schedule() {
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 35);
    for &threads in &[1usize, 4] {
        for mode in MODES {
            let mut g = trainer(&eng, 2, 4, threads, mode, PpSched::GPipe);
            g.train_step(&b).unwrap();
            assert_eq!(g.stash_len(), 0, "gpipe {mode:?} t{threads}");
            assert_eq!(g.stash_peaks(), vec![4, 4]);
            let mut f =
                trainer(&eng, 2, 4, threads, mode, PpSched::OneFOneB);
            f.train_step(&b).unwrap();
            assert_eq!(f.stash_len(), 0, "1f1b {mode:?} t{threads}");
            assert_eq!(f.stash_peaks(), vec![2, 1]);
            assert_eq!(f.predicted_peak_stash(), 2);
        }
    }
}

/// Reversed gradient sends hit the ledger with single-peer accounting:
/// one forward and one backward hand-off per (micro-batch, boundary),
/// payload = one [micro_batch, seq, d_model] f32 tensor each way,
/// identical bytes under both schedules.
#[test]
fn reversed_sends_are_accounted_per_boundary() {
    let eng = NativeBackend::synthetic();
    let b = batch(&eng, 36);
    let mut counts = Vec::new();
    for sched in SCHEDS {
        let mut t = trainer(&eng, 4, 2, 2, SchedMode::Graph, sched);
        t.train_step(&b).unwrap();
        let s = t.ledger.stats();
        let sends = (2 * t.micro * (t.stages - 1)) as u64;
        assert_eq!(s.broadcasts, sends, "{}", sched.name());
        let payload =
            (t.micro_batch * t.cfg.seq_len * t.cfg.d_model * 4) as f64;
        assert_eq!(
            s.broadcast_bytes,
            sends as f64 * payload,
            "{}",
            sched.name()
        );
        counts.push((s.broadcasts, s.broadcast_bytes.to_bits()));
    }
    assert_eq!(counts[0], counts[1], "schedules moved different bytes");
}
