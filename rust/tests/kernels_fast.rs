//! Tolerance contract of the `fast` kernel tier (`--kernels fast` /
//! `FAL_KERNELS=fast`) against the bit-exact `exact` tier.
//!
//! The fast tier trades the exact tier's fixed accumulation order for
//! SIMD-width multi-accumulator reductions, a Padé tanh and bf16 storage,
//! so it is *not* bit-identical to exact — but it must stay (a) within
//! per-kernel atol/rtol bounds of the exact result, (b) deterministic in
//! itself at every thread count and schedule, and (c) close enough that a
//! short training run's loss trajectory tracks the exact tier. Chunked
//! all-reduces (the fast tier's comm shape) must be bitwise identical to
//! the unchunked collective with chunk-count-invariant ledger accounting.

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::collectives::{chunk_row_ranges, CommLedger};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::runtime::native::kernels::{
    gelu, layernorm, matmul, matmul_nt, softmax_rows,
};
use fal::runtime::{ExecCtx, KernelTier, NativeBackend};
use fal::tensor::{bf16_round, DType, HostTensor};
use fal::util::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 7];

fn exact(t: usize) -> ExecCtx {
    ExecCtx::new(t).with_kernels(KernelTier::Exact)
}

fn fast(t: usize) -> ExecCtx {
    ExecCtx::new(t).with_kernels(KernelTier::Fast)
}

/// Assert `got` is within `atol + rtol * |want|` of `want`, elementwise.
fn assert_close(got: &HostTensor, want: &HostTensor, atol: f32, rtol: f32, what: &str) {
    assert_eq!(got.shape, want.shape, "{what}: shape mismatch");
    for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        let bound = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= bound,
            "{what}[{i}]: fast {g} vs exact {w} (bound {bound})"
        );
    }
}

fn bits(t: &HostTensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn fast_matmul_family_tolerance_and_thread_invariance() {
    let mut rng = Rng::new(11);
    let a = HostTensor::randn(&[3, 17, 29], 1.0, &mut rng);
    let b = HostTensor::randn(&[29, 13], 1.0, &mut rng);
    let bt = HostTensor::randn(&[13, 29], 1.0, &mut rng);
    let mm_ref = matmul(&exact(1), &a, &b);
    let nt_ref = matmul_nt(&exact(1), &a, &bt);
    // k=29 at unit-variance inputs: reassociation error stays well under
    // 1e-4 absolute / 1e-5 relative.
    let (mm_bits, nt_bits) = (
        bits(&matmul(&fast(1), &a, &b)),
        bits(&matmul_nt(&fast(1), &a, &bt)),
    );
    for t in THREADS {
        let mm = matmul(&fast(t), &a, &b);
        let nt = matmul_nt(&fast(t), &a, &bt);
        assert_close(&mm, &mm_ref, 1e-4, 1e-5, "matmul");
        assert_close(&nt, &nt_ref, 1e-4, 1e-5, "matmul_nt");
        // The fast tier is still deterministic per tier: identical bits
        // at every thread count (lane count fixed, partition-independent).
        assert_eq!(bits(&mm), mm_bits, "fast matmul drifts at t={t}");
        assert_eq!(bits(&nt), nt_bits, "fast matmul_nt drifts at t={t}");
    }
}

#[test]
fn fast_elementwise_kernels_within_tolerance() {
    let mut rng = Rng::new(23);
    let x = HostTensor::randn(&[5, 9, 33], 1.5, &mut rng);
    let g = HostTensor::randn(&[33], 0.3, &mut rng);
    let b = HostTensor::randn(&[33], 0.1, &mut rng);
    let gelu_ref = gelu(&exact(1), &x);
    let ln_ref = layernorm(&exact(1), &x, &g, &b);
    let sm_ref = softmax_rows(&exact(1), &x);
    for t in THREADS {
        // gelu: the Padé tanh is within 2e-4 of libm tanh, and the GeLU
        // prefactor halves it.
        assert_close(&gelu(&fast(t), &x), &gelu_ref, 2e-4, 1e-4, "gelu");
        // layernorm: mean/variance via lane-split sums — pure
        // reassociation noise on 33-element rows.
        assert_close(
            &layernorm(&fast(t), &x, &g, &b),
            &ln_ref,
            1e-5,
            1e-5,
            "layernorm",
        );
        // softmax: exp is shared; only the denominator sum reassociates.
        assert_close(
            &softmax_rows(&fast(t), &x),
            &sm_ref,
            1e-6,
            1e-5,
            "softmax_rows",
        );
    }
}

#[test]
fn bf16_round_trip_bounds() {
    // RNE to bf16's 7 explicit mantissa bits: relative error ≤ 2^-8 =
    // 1/256 for normal values, exact on values already representable.
    let mut rng = Rng::new(5);
    let t = HostTensor::randn(&[64], 3.0, &mut rng);
    let q = t.bf16();
    assert_eq!(q.dtype, DType::Bf16);
    assert_eq!(q.size_bytes(), t.size_bytes() / 2);
    for (v, w) in t.data.iter().zip(&q.data) {
        assert!(
            (v - w).abs() <= v.abs() / 256.0,
            "bf16 round {v} -> {w} out of bounds"
        );
    }
    for v in [0.0f32, -1.0, 2.0, 0.5, 1.0 + 1.0 / 128.0, f32::INFINITY] {
        assert_eq!(bf16_round(v), v, "representable value must be exact");
    }
    assert!(bf16_round(f32::NAN).is_nan());
}

#[test]
fn chunked_allreduce_matches_unchunked_and_accounting_is_chunk_invariant() {
    let mut rng = Rng::new(41);
    let parts: Vec<HostTensor> = (0..4)
        .map(|_| HostTensor::randn(&[19, 23], 1.0, &mut rng))
        .collect();
    let refs: Vec<&HostTensor> = parts.iter().collect();
    let ctx = exact(4);
    let base_l = CommLedger::new(PCIE_GEN4, 4);
    let want = base_l.all_reduce_refs(&ctx, &refs);
    for chunks in [1, 2, 3, 5, 64] {
        let l = CommLedger::new(PCIE_GEN4, 4);
        let got = l.all_reduce_chunked(&ctx, &refs, chunks);
        // Chunking only splits rows across comm nodes; per-element the
        // reduction is the same ascending-rank sum — bitwise equal.
        assert_eq!(bits(&got), bits(&want), "chunks={chunks}");
        assert_eq!(got.shape, want.shape);
        // One step's ledger story (count, bytes, modeled secs) must not
        // depend on how many wire chunks carried it.
        assert_eq!(l.stats(), base_l.stats(), "chunks={chunks}");
    }
    // Degenerate payloads: fewer rows than chunks, single row.
    for rows in [1usize, 3] {
        let p: Vec<HostTensor> = (0..2)
            .map(|_| HostTensor::randn(&[rows, 7], 1.0, &mut rng))
            .collect();
        let pr: Vec<&HostTensor> = p.iter().collect();
        let l = CommLedger::new(PCIE_GEN4, 2);
        let got = l.all_reduce_chunked(&ctx, &pr, 8);
        let want = CommLedger::new(PCIE_GEN4, 2).all_reduce_refs(&ctx, &pr);
        assert_eq!(bits(&got), bits(&want), "rows={rows}");
    }
    let covered: usize = chunk_row_ranges(19, 4).iter().map(|r| r.len()).sum();
    assert_eq!(covered, 19);
}

#[test]
fn fast_tier_loss_trajectory_tracks_exact() {
    // Short TP train run (which also exercises the fast tier's chunked
    // all-reduce graph nodes): the fast tier's per-step loss must track
    // the exact tier within a small relative divergence bound.
    let run = |tier: KernelTier| -> Vec<f32> {
        let eng = NativeBackend::synthetic_with_ctx(
            ExecCtx::new(4).with_kernels(tier),
        );
        let cfg = fal::runtime::Backend::manifest(&eng)
            .config("tiny")
            .unwrap()
            .clone();
        let corpus = Corpus::generate(
            CorpusSpec::for_vocab(cfg.vocab_size), 20_000, 3);
        let mut loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, 7);
        let mut t = TpTrainer::new(
            &eng, "tiny", Variant::Fal, 2, PCIE_GEN4,
            TrainConfig::default(),
        )
        .unwrap();
        (0..4)
            .map(|_| {
                let b = loader.next_train();
                t.train_step(&b).unwrap().0
            })
            .collect()
    };
    let le = run(KernelTier::Exact);
    let lf = run(KernelTier::Fast);
    for (i, (e, f)) in le.iter().zip(&lf).enumerate() {
        assert!(f.is_finite(), "fast loss diverged at step {i}");
        let rel = (e - f).abs() / e.abs().max(1e-6);
        assert!(
            rel < 2e-2,
            "step {i}: exact {e} vs fast {f} (rel {rel})"
        );
    }
}
