//! L3 hot-path microbenches (the §Perf profile): native stage dispatch,
//! collectives, compression codecs, corpus/loader — plus literal
//! conversion and engine dispatch when built with `--features pjrt` and
//! `make artifacts`.
//!
//! `cargo bench --bench runtime_hotpath [-- --filter allreduce]`

use fal::comm::error_feedback::ErrorFeedback;
use fal::comm::powersgd::PowerSgd;
use fal::comm::qsgd::Qsgd;
use fal::config::PCIE_GEN4;
use fal::coordinator::collectives::CommLedger;
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::runtime::{Backend, Manifest, NativeBackend};
use fal::tensor::HostTensor;
use fal::util::benchkit::Bench;
use fal::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(0);

    #[cfg(feature = "pjrt")]
    {
        // HostTensor <-> Literal conversion (1M f32).
        let t1m = HostTensor::randn(&[1024, 1024], 1.0, &mut rng);
        b.bench("literal_convert_roundtrip_4MB", 4e6, || {
            let l = fal::runtime::to_literal(&t1m).unwrap();
            fal::runtime::from_literal(&l).unwrap().len()
        });
    }

    // Collectives: all-reduce of 4 x 1 MB shards.
    let ledger = CommLedger::new(PCIE_GEN4, 4);
    let shards: Vec<HostTensor> = (0..4)
        .map(|i| HostTensor::randn(&[256 * 1024], 1.0, &mut Rng::new(i)))
        .collect();
    b.bench("allreduce_4x1MB", 4e6, || {
        ledger.all_reduce(&shards).len()
    });

    // Compression codecs on a 192x768 gradient (the small config's w1).
    let grad = HostTensor::randn(&[192, 768], 0.02, &mut rng);
    let mut qsgd = ErrorFeedback::new(Qsgd::new(4, 512, 7));
    b.bench("qsgd_ef_transmit_147k", grad.len() as f64, || {
        qsgd.transmit("w", &grad).1
    });
    let mut psgd = ErrorFeedback::new(PowerSgd::new(4, 7));
    b.bench("powersgd_ef_transmit_147k", grad.len() as f64, || {
        psgd.transmit("w", &grad).1
    });

    // Data pipeline.
    b.bench("corpus_generate_100k_tokens", 100_000.0, || {
        Corpus::generate(CorpusSpec::for_vocab(1024), 100_000, 1)
            .tokens
            .len()
    });
    let corpus = Corpus::generate(CorpusSpec::for_vocab(1024), 600_000, 1);
    let mut loader = Loader::new(&corpus, 96, 8, 0.05, 2);
    b.bench("loader_next_train_batch", (8 * 96) as f64, || {
        loader.next_train().tokens.len()
    });

    // Native backend: per-stage dispatch cost on the tiny attention stage
    // (validation + kernel; the collectives above isolate the reduction).
    let native = NativeBackend::synthetic();
    let stage = Manifest::tp_stage_name("tiny", 2, 4, "attn_fwd");
    let spec = native.manifest().artifact(&stage).unwrap().clone();
    let stage_inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| {
            if s.name.ends_with("_g") {
                HostTensor::ones(&s.shape)
            } else {
                HostTensor::randn(&s.shape, 0.05, &mut rng)
            }
        })
        .collect();
    let stage_tokens = spec.inputs[0].shape.iter().product::<usize>() as f64;
    b.bench("native_attn_fwd_tiny_tp2", stage_tokens, || {
        native.execute(&stage, &stage_inputs).unwrap()[0].data[0]
    });

    #[cfg(feature = "pjrt")]
    {
        // Engine: tiny eval executable end-to-end (compile amortized).
        use fal::runtime::Engine;
        let dir =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(engine) = Engine::new(&dir) {
            if let Ok(spec) =
                engine.manifest.find("eval_masked", "tiny", "preln")
            {
                let name = spec.name.clone();
                let batch =
                    spec.meta.get("batch").unwrap().as_usize().unwrap();
                let cfg = engine.manifest.config("tiny").unwrap().clone();
                let params = engine.manifest.load_params("tiny", 0).unwrap();
                let mut inputs = params;
                let toks: Vec<i32> = (0..batch * cfg.seq_len)
                    .map(|i| (i % cfg.vocab_size) as i32)
                    .collect();
                inputs
                    .push(HostTensor::from_i32(&[batch, cfg.seq_len], &toks));
                inputs
                    .push(HostTensor::from_i32(&[batch, cfg.seq_len], &toks));
                inputs.push(HostTensor::ones(&[cfg.n_layer]));
                inputs.push(HostTensor::ones(&[cfg.n_layer]));
                engine.execute(&name, &inputs).unwrap(); // compile
                b.bench(
                    "engine_execute_tiny_eval",
                    (batch * cfg.seq_len) as f64,
                    || engine.execute(&name, &inputs).unwrap()[0].data[0],
                );
            }
        } else {
            eprintln!("(skip engine benches: run `make artifacts` first)");
        }
    }

    println!("\n== summary ==\n{}", b.summary());
}
