//! L3 hot-path microbenches (the §Perf profile): ExecCtx kernel scoreboard
//! (matmul / attention / layernorm at 1, 2 and 4 threads), the fused
//! native train step, native stage dispatch, collectives, compression
//! codecs, corpus/loader — plus literal conversion and engine dispatch
//! when built with `--features pjrt` and `make artifacts`.
//!
//! Scoreboard cases (threads in the name) are persisted to
//! `BENCH_native.json` (override with `FAL_BENCH_JSON`) so the perf
//! trajectory is tracked across PRs: the ExecCtx acceptance bar is the
//! `*_t4` rows showing a multi-x speedup over their `*_t1` baselines.
//!
//! `cargo bench --bench runtime_hotpath [-- --filter matmul]`

use fal::comm::error_feedback::ErrorFeedback;
use fal::comm::powersgd::PowerSgd;
use fal::comm::qsgd::Qsgd;
use fal::config::PCIE_GEN4;
use fal::coordinator::collectives::CommLedger;
use fal::coordinator::sp_trainer::{Schedule, Trainer};
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::runtime::native::kernels;
use fal::runtime::{
    Backend, ExecCtx, KernelTier, Manifest, NativeBackend, SchedMode,
};
use fal::tensor::HostTensor;
use fal::util::benchkit::{Bench, CaseMeta};
use fal::util::rng::Rng;

/// Thread counts the scoreboard tracks (t1 is the scalar baseline).
const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(0);

    #[cfg(feature = "pjrt")]
    {
        // HostTensor <-> Literal conversion (1M f32).
        let t1m = HostTensor::randn(&[1024, 1024], 1.0, &mut rng);
        b.bench("literal_convert_roundtrip_4MB", 4e6, || {
            let l = fal::runtime::to_literal(&t1m).unwrap();
            fal::runtime::from_literal(&l).unwrap().len()
        });
    }

    // ------------------------------------------------------------------
    // ExecCtx kernel scoreboard: the small config's token panel
    // ([b*s, d] = [1024, 192]) against its MLP up-projection [192, 768].
    // ------------------------------------------------------------------
    let a = HostTensor::randn(&[1024, 192], 0.5, &mut rng);
    let w = HostTensor::randn(&[192, 768], 0.02, &mut rng);
    let wt = HostTensor::randn(&[768, 192], 0.02, &mut rng);
    let up = HostTensor::randn(&[1024, 768], 0.5, &mut rng);
    let flops_mm = (2 * 1024 * 192 * 768) as f64;
    for threads in THREADS {
        // matmul / matmul_nt carry exact-vs-fast scoreboard pairs: the
        // fast rows are the SIMD microkernel tier (`--kernels fast`), the
        // acceptance bar being >= 1.2x over the exact rows at t4.
        for tier in [KernelTier::Exact, KernelTier::Fast] {
            let ctx = ExecCtx::new(threads).with_kernels(tier);
            b.bench_case(
                &format!("matmul_1024x192x768_{}_t{threads}", tier.name()),
                CaseMeta::new(
                    "matmul",
                    &format!("1024x192x768/kernels={}", tier.name()),
                    threads,
                ),
                flops_mm,
                || kernels::matmul(&ctx, &a, &w).data[0],
            );
            b.bench_case(
                &format!("matmul_nt_1024x192x768_{}_t{threads}", tier.name()),
                CaseMeta::new(
                    "matmul_nt",
                    &format!("1024x192x768/kernels={}", tier.name()),
                    threads,
                ),
                flops_mm,
                || kernels::matmul_nt(&ctx, &a, &wt).data[0],
            );
        }
        let ctx = ExecCtx::new(threads).with_kernels(KernelTier::Exact);
        b.bench_case(
            &format!("matmul_tn_1024x192x768_t{threads}"),
            CaseMeta::new("matmul_tn", "1024x192x768", threads),
            flops_mm,
            || kernels::matmul_tn(&ctx, &a, &up).data[0],
        );
    }

    // Attention fwd/bwd + LayerNorm bwd at the small-config block shape.
    let geom = kernels::AttnGeom {
        batch: 8,
        seq: 128,
        heads: 8,
        kv_heads: 8,
        head_dim: 24,
    };
    let q = HostTensor::randn(&[8, 128, 192], 0.3, &mut rng);
    let k = HostTensor::randn(&[8, 128, 192], 0.3, &mut rng);
    let v = HostTensor::randn(&[8, 128, 192], 0.3, &mut rng);
    let dout = HostTensor::randn(&[8, 128, 192], 1.0, &mut rng);
    let gamma = HostTensor::ones(&[192]);
    let attn_units = (8 * 8 * 128 * 128) as f64; // (b*h) score cells
    for threads in THREADS {
        let ctx = ExecCtx::new(threads);
        b.bench_case(
            &format!("attn_fwd_b8s128h8_t{threads}"),
            CaseMeta::new("causal_attention", "b8s128h8d24", threads),
            attn_units,
            || kernels::causal_attention(&ctx, &geom, &q, &k, &v).data[0],
        );
        b.bench_case(
            &format!("attn_bwd_b8s128h8_t{threads}"),
            CaseMeta::new("causal_attention_bwd", "b8s128h8d24", threads),
            attn_units,
            || kernels::causal_attention_bwd(&ctx, &geom, &q, &k, &v, &dout).0.data[0],
        );
        b.bench_case(
            &format!("layernorm_bwd_1024x192_t{threads}"),
            CaseMeta::new("layernorm_bwd", "1024x192", threads),
            (1024 * 192) as f64,
            || kernels::layernorm_bwd(&ctx, &a, &gamma, &a).0.data[0],
        );
    }

    // ------------------------------------------------------------------
    // Fused native train step (loss + grads + AdamW) on the small config,
    // per StageGraph schedule: the `graph` rows run the FAL blocks'
    // MHA ∥ MLP branches on concurrent worker lanes, the `serial` rows the
    // historical back-to-back schedule — the MHA‖MLP overlap speedup is
    // the graph-vs-serial delta at the same thread count (t >= 2).
    // ------------------------------------------------------------------
    {
        let cfg_tokens = (8 * 128) as f64;
        let corpus = Corpus::generate(CorpusSpec::for_vocab(512), 50_000, 1);
        for threads in [1usize, 2, 4] {
            // At threads = 1 the two schedules are the same code path by
            // construction — one baseline row suffices.
            let scheds: &[SchedMode] = if threads == 1 {
                &[SchedMode::Serial]
            } else {
                &[SchedMode::Serial, SchedMode::Graph]
            };
            for &sched in scheds {
                let engine = NativeBackend::synthetic_with_ctx(
                    ExecCtx::new(threads).with_sched(sched),
                );
                let cfg = engine.manifest().config("small").unwrap().clone();
                let loader = Loader::new(&corpus, cfg.seq_len, 8, 0.1, 2);
                let batch = loader.fixed_batch(3);
                let mut t =
                    Trainer::new(&engine, "small", "fal", Schedule::Constant)
                        .unwrap();
                t.train_step(&batch).unwrap(); // warm
                b.bench_case(
                    &format!(
                        "fused_train_step_small_fal_t{threads}_{}",
                        sched.name()
                    ),
                    CaseMeta::new(
                        "train_step",
                        &format!("small/fal/{}", sched.name()),
                        threads,
                    ),
                    cfg_tokens,
                    || t.train_step(&batch).unwrap().loss,
                );
            }
        }
    }

    // Collectives: all-reduce of 4 x 1 MB shards.
    let ledger = CommLedger::new(PCIE_GEN4, 4);
    let shards: Vec<HostTensor> = (0..4)
        .map(|i| HostTensor::randn(&[256 * 1024], 1.0, &mut Rng::new(i)))
        .collect();
    b.bench("allreduce_4x1MB", 4e6, || {
        ledger.all_reduce(&shards).len()
    });

    // Compression codecs on a 192x768 gradient (the small config's w1).
    let grad = HostTensor::randn(&[192, 768], 0.02, &mut rng);
    let mut qsgd = ErrorFeedback::new(Qsgd::new(4, 512, 7));
    b.bench("qsgd_ef_transmit_147k", grad.len() as f64, || {
        qsgd.transmit("w", &grad).1
    });
    let mut psgd = ErrorFeedback::new(PowerSgd::new(4, 7));
    b.bench("powersgd_ef_transmit_147k", grad.len() as f64, || {
        psgd.transmit("w", &grad).1
    });

    // Data pipeline.
    b.bench("corpus_generate_100k_tokens", 100_000.0, || {
        Corpus::generate(CorpusSpec::for_vocab(1024), 100_000, 1)
            .tokens
            .len()
    });
    let corpus = Corpus::generate(CorpusSpec::for_vocab(1024), 600_000, 1);
    let mut loader = Loader::new(&corpus, 96, 8, 0.05, 2);
    b.bench("loader_next_train_batch", (8 * 96) as f64, || {
        loader.next_train().tokens.len()
    });

    // Native backend: per-stage dispatch cost on the tiny attention stage
    // (validation + kernel; the collectives above isolate the reduction).
    let native = NativeBackend::synthetic();
    let stage = Manifest::tp_stage_name("tiny", 2, 4, "attn_fwd");
    let spec = native.manifest().artifact(&stage).unwrap().clone();
    let stage_inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| {
            if s.name.ends_with("_g") {
                HostTensor::ones(&s.shape)
            } else {
                HostTensor::randn(&s.shape, 0.05, &mut rng)
            }
        })
        .collect();
    let stage_tokens = spec.inputs[0].shape.iter().product::<usize>() as f64;
    b.bench("native_attn_fwd_tiny_tp2", stage_tokens, || {
        native.execute(&stage, &stage_inputs).unwrap()[0].data[0]
    });

    #[cfg(feature = "pjrt")]
    {
        // Engine: tiny eval executable end-to-end (compile amortized).
        use fal::runtime::Engine;
        let dir =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(engine) = Engine::new(&dir) {
            if let Ok(spec) =
                engine.manifest.find("eval_masked", "tiny", "preln")
            {
                let name = spec.name.clone();
                let batch =
                    spec.meta.get("batch").unwrap().as_usize().unwrap();
                let cfg = engine.manifest.config("tiny").unwrap().clone();
                let params = engine.manifest.load_params("tiny", 0).unwrap();
                let mut inputs = params;
                let toks: Vec<i32> = (0..batch * cfg.seq_len)
                    .map(|i| (i % cfg.vocab_size) as i32)
                    .collect();
                inputs
                    .push(HostTensor::from_i32(&[batch, cfg.seq_len], &toks));
                inputs
                    .push(HostTensor::from_i32(&[batch, cfg.seq_len], &toks));
                inputs.push(HostTensor::ones(&[cfg.n_layer]));
                inputs.push(HostTensor::ones(&[cfg.n_layer]));
                engine.execute(&name, &inputs).unwrap(); // compile
                b.bench(
                    "engine_execute_tiny_eval",
                    (batch * cfg.seq_len) as f64,
                    || engine.execute(&name, &inputs).unwrap()[0].data[0],
                );
            }
        } else {
            eprintln!("(skip engine benches: run `make artifacts` first)");
        }
    }

    println!("\n== summary ==\n{}", b.summary());
    match b.write_json_default() {
        Ok(path) => println!("scoreboard: {}", path.display()),
        Err(e) => eprintln!("warning: could not write scoreboard: {e}"),
    }
}
