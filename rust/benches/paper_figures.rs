//! Paper-figure benches: regenerate every cost-model table/figure and time
//! the generation itself. One bench per paper artifact (Fig 6, Fig 8,
//! Fig 10, Fig 19), printing the same rows the paper reports.
//!
//! `cargo bench --bench paper_figures [-- --filter fig6]`

use fal::config::{
    ModelConfig, Variant, H200, NVLINK, PCIE_GEN4, RTX_3090, RTX_4090,
    RTX_A6000,
};
use fal::coordinator::dp_pp::{dp_cost, pp_cost, tp_cost};
use fal::costmodel::timemodel::{
    inference_time, single_gpu_throughput, train_step_time,
};
use fal::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();

    // Fig 6: multi-GPU normalized training time.
    b.bench("fig6_multigpu_sweep (24 cells)", 24.0, || {
        let mut acc = 0.0;
        for (gpu, link) in [(&H200, &NVLINK), (&RTX_3090, &PCIE_GEN4)] {
            for scale in ["774M", "1.5B", "2.5B", "8.3B"] {
                let cfg = ModelConfig::paper_scale(scale).unwrap();
                for tp in [2usize, 4, 8] {
                    let base = train_step_time(
                        &cfg, Variant::PreLn, gpu, link, tp, 8 * tp, true);
                    let fal = train_step_time(
                        &cfg, Variant::Fal, gpu, link, tp, 8 * tp, true);
                    acc += fal.total() / base.total();
                }
            }
        }
        acc
    });

    // Fig 8a: single-GPU throughput ratios on three GPUs x flash on/off.
    b.bench("fig8_single_gpu_ratios (6 cells)", 6.0, || {
        let cfg = ModelConfig::paper_scale("774M").unwrap();
        let mut acc = 0.0;
        for gpu in [&RTX_3090, &RTX_4090, &RTX_A6000] {
            for flash in [false, true] {
                acc += single_gpu_throughput(&cfg, Variant::Fal, gpu, 8, flash)
                    / single_gpu_throughput(
                        &cfg, Variant::PreLn, gpu, 8, flash);
            }
        }
        acc
    });

    // Fig 10: DP vs PP vs TP.
    b.bench("fig10_parallelism_compare", 4.0, || {
        let mut cfg = ModelConfig::paper_scale("774M").unwrap();
        cfg.n_layer = 42;
        cfg.n_params = cfg.count_params();
        let dp = dp_cost(&cfg, &RTX_3090, &PCIE_GEN4, 2, 2);
        let pp = pp_cost(&cfg, &RTX_3090, &PCIE_GEN4, 2, 2, 4);
        let tp = tp_cost(&cfg, Variant::PreLn, &RTX_3090, &PCIE_GEN4, 2, 2);
        let fal = tp_cost(&cfg, Variant::Fal, &RTX_3090, &PCIE_GEN4, 2, 2);
        dp.step_secs + pp.step_secs + tp.step_secs + fal.step_secs
    });

    // Fig 19: inference TTFT sweep.
    b.bench("fig19_inference_sweep (48 cells)", 48.0, || {
        let mut acc = 0.0;
        for scale in ["774M", "2.5B", "8.3B"] {
            let cfg = ModelConfig::paper_scale(scale).unwrap();
            for seq in [1024usize, 2048] {
                for tp in [1usize, 2, 4, 8] {
                    acc += inference_time(
                        &cfg, Variant::PreLn, &H200, &NVLINK, tp, 1, seq);
                    acc += inference_time(
                        &cfg, Variant::Fal, &H200, &NVLINK, tp, 1, seq);
                }
            }
        }
        acc
    });

    println!("\n== summary ==\n{}", b.summary());
}
