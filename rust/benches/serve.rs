//! `fal serve` decode-path bench: KV-cache decode step wall-clock under
//! all three StageGraph schedules, plus the continuous-batching engine's
//! *virtual* serving scoreboard — tokens/sec against the costmodel clock,
//! p50/p99 per-token and time-to-first-token latencies, and mean batch
//! occupancy — for Pre-LN vs FAL vs FAL+ at tp=2 on the micro config.
//!
//! Wall-clock rows (`serve_decode_step_*`) track the real cost of one
//! `[B, 1, D]` decode step across PRs; the virtual rows are deterministic
//! (seeded workload + costmodel clock, no wall time), so their scoreboard
//! trajectory moves only when the schedule or the cost model does.
//! Latency rows encode virtual seconds directly (ns_per_iter = secs ×
//! 1e9); the throughput row samples the virtual run time with generated
//! tokens as units, so `thr` is virtual tokens/sec. Runs with default
//! features: no artifacts needed.
//!
//! `cargo bench --bench serve`

use fal::config::{Variant, PCIE_GEN4, RTX_3090};
use fal::coordinator::serve::{poisson_workload, Decoder, ServeEngine};
use fal::runtime::{ExecCtx, NativeBackend, SchedMode};
use fal::util::benchkit::{Bench, CaseMeta};

fn main() {
    let base_ctx = ExecCtx::from_env();
    let threads = base_ctx.threads();
    let mut b = Bench::from_env();

    for (variant, name) in [
        (Variant::PreLn, "preln"),
        (Variant::Fal, "fal"),
        (Variant::FalPlus, "falplus"),
    ] {
        // One decode step (admit-free, fixed batch) under each schedule:
        // graph-vs-serial is the rank-/branch-parallel win, overlap-vs-
        // graph the comm-node drain win once comm is simulated.
        for sched in
            [SchedMode::Serial, SchedMode::Graph, SchedMode::Overlap]
        {
            let engine = NativeBackend::synthetic_with_ctx(
                base_ctx.with_sched(sched),
            );
            let mut dec =
                Decoder::new(&engine, "micro", variant, 2, PCIE_GEN4)
                    .unwrap();
            let batch = dec.batch;
            let seq = dec.cfg.seq_len;
            let toks: Vec<i32> = (0..batch)
                .map(|i| ((i * 7 + 3) % dec.cfg.vocab_size) as i32)
                .collect();
            dec.step(&toks, &vec![0; batch]).unwrap(); // warm
            let mut p = 0usize;
            b.bench_case(
                &format!(
                    "serve_micro_decode_step_{name}_t{threads}_{}",
                    sched.name()
                ),
                CaseMeta::new(
                    "serve_decode_step",
                    &format!("micro/{name}/{}", sched.name()),
                    threads,
                ),
                batch as f64,
                || {
                    p = (p + 1) % seq;
                    dec.step(&toks, &vec![p; batch]).unwrap()
                },
            );
        }

        // The virtual serving scoreboard: one deterministic 64-request
        // run per variant. These numbers are clock-model outputs, not
        // wall time — bit-identical across machines and thread counts.
        let engine = NativeBackend::synthetic_with_ctx(
            base_ctx.with_sched(SchedMode::Graph),
        );
        let dec =
            Decoder::new(&engine, "micro", variant, 2, PCIE_GEN4).unwrap();
        let cfg = dec.cfg.clone();
        let reqs = poisson_workload(&cfg, 64, 17, 400.0);
        let mut srv = ServeEngine::new(dec, RTX_3090);
        let r = srv.run(&reqs).unwrap();
        println!(
            "{name}: {} tok in {:.3} virtual ms — {:.0} tok/s, occupancy \
             {:.2}, p50/p99 token {:.1}/{:.1} us, p50/p99 ttft \
             {:.1}/{:.1} us",
            r.generated_tokens,
            r.virtual_secs * 1e3,
            r.tokens_per_sec,
            r.mean_occupancy,
            r.p50_token_secs * 1e6,
            r.p99_token_secs * 1e6,
            r.p50_ttft_secs * 1e6,
            r.p99_ttft_secs * 1e6,
        );
        b.record_case(
            &format!("serve_micro_virtual_tput_{name}_t{threads}"),
            CaseMeta::new(
                "serve_virtual_tput",
                &format!("micro/{name}/tp2"),
                threads,
            ),
            &[r.virtual_secs],
            r.generated_tokens as f64,
        );
        for (tag, secs) in [
            ("p50_token", r.p50_token_secs),
            ("p99_token", r.p99_token_secs),
            ("p50_ttft", r.p50_ttft_secs),
            ("p99_ttft", r.p99_ttft_secs),
        ] {
            b.record_case(
                &format!("serve_micro_{tag}_{name}_t{threads}"),
                CaseMeta::new(
                    "serve_virtual_latency",
                    &format!("micro/{name}/tp2/{tag}"),
                    threads,
                ),
                &[secs],
                0.0,
            );
        }
        b.record_case(
            &format!("serve_micro_occupancy_{name}_t{threads}"),
            CaseMeta::new(
                "serve_occupancy",
                &format!("micro/{name}/tp2"),
                threads,
            ),
            &[r.mean_occupancy],
            0.0,
        );
    }

    println!("\n== summary ==\n{}", b.summary());
    println!(
        "(decode-vs-full-forward bitwise equality is asserted in \
         tests/serve_decode.rs; the virtual rows move only with the \
         schedule or cost model, the decode_step rows with the kernels)"
    );
    match b.write_json_default() {
        Ok(path) => println!("scoreboard: {}", path.display()),
        Err(e) => eprintln!("warning: could not write scoreboard: {e}"),
    }
}
