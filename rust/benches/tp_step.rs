//! End-to-end TP coordinator step bench (tiny config): the paper's central
//! comparison run live — Pre-LN (2 AR/block) vs FAL (1 AR/block) — with the
//! real sharded stage kernels on the native backend, under all three
//! StageGraph schedules (`serial` = the historical rank loop, `graph` =
//! rank-parallel shard nodes + MHA ∥ MLP branch fork, `overlap` =
//! dependency-driven with all-reduce comm nodes drained in flight). Also
//! times forward-only (TTFT path) and measures the **realized overlap
//! fraction** under a simulated `costmodel` link — how much of the comm
//! wall-clock hides inside compute spans — against
//! `costmodel::timemodel::predicted_hidden_fraction`. The executed
//! pipeline gets the same treatment: gpipe-vs-1f1b fwd+bwd step rows at
//! (stages 2, micro 4), plus realized-vs-predicted bubble-fraction rows
//! against `timemodel::pipeline_bubble_fraction`. Runs with default
//! features: no artifacts needed.
//!
//! Cases are persisted to `BENCH_native.json` (override with
//! `FAL_BENCH_JSON`) alongside the runtime_hotpath scoreboard; the thread
//! count is whatever `FAL_THREADS` resolves to, and the schedule is part
//! of the case name so `*_graph` vs `*_serial` vs `*_overlap` rows track
//! the overlap speedup across PRs. The fraction rows encode a fraction as
//! "seconds" (ns_per_iter = fraction × 1e9).
//!
//! `cargo bench --bench tp_step`

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::dp_pp::{PpSched, PpTrainer};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::costmodel::timemodel::{
    pipeline_bubble_fraction, predicted_hidden_fraction,
};
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::runtime::sched::{COMM_BUCKET, COMPUTE_BUCKET};
use fal::runtime::{Backend, ExecCtx, KernelTier, NativeBackend, SchedMode};
use fal::util::benchkit::{Bench, CaseMeta};

fn main() {
    let base_ctx = ExecCtx::from_env();
    let threads = base_ctx.threads();
    let probe = NativeBackend::synthetic();
    let cfg = probe.manifest().config("tiny").unwrap().clone();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 50_000, 1);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, 2);
    let batch = loader.fixed_batch(3);
    let tokens_per_step = (4 * cfg.seq_len) as f64;

    let mut b = Bench::from_env();
    for (variant, name) in
        [(Variant::PreLn, "preln"), (Variant::Fal, "fal")]
    {
        // Train step under all three schedules: graph-vs-serial is the
        // rank-parallel + branch-fork win; overlap-vs-graph is the
        // comm-node eager-drain win (visible once comm is simulated; with
        // the real host-memory collectives the three are near-identical).
        for sched in
            [SchedMode::Serial, SchedMode::Graph, SchedMode::Overlap]
        {
            let engine =
                NativeBackend::synthetic_with_ctx(base_ctx.with_sched(sched));
            let mut t = TpTrainer::new(
                &engine, "tiny", variant, 2, PCIE_GEN4,
                TrainConfig::default())
            .unwrap();
            // Warm the stage executables.
            t.train_step(&batch).unwrap();
            // Thread count and schedule are part of the case name:
            // write_json merges by name, so runs at different FAL_THREADS
            // / schedules must not clobber each other's scoreboard rows.
            b.bench_case(
                &format!(
                    "tp2_tiny_train_step_{name}_t{threads}_{}",
                    sched.name()
                ),
                CaseMeta::new(
                    "tp_train_step",
                    &format!("tiny/{name}/{}", sched.name()),
                    threads,
                ),
                tokens_per_step,
                || t.train_step(&batch).unwrap().0,
            );
        }
        // Forward-only (TTFT) under the default graph schedule. The sched
        // suffix keeps this row from merge-colliding with the pre-sched
        // (serial-loop) measurements of earlier scoreboards.
        let engine =
            NativeBackend::synthetic_with_ctx(base_ctx.with_sched(SchedMode::Graph));
        let mut f = TpTrainer::new(
            &engine, "tiny", variant, 2, PCIE_GEN4, TrainConfig::default())
        .unwrap();
        f.forward_loss(&batch).unwrap();
        b.bench_case(
            &format!("tp2_tiny_forward_{name}_t{threads}_graph"),
            CaseMeta::new("tp_forward", &format!("tiny/{name}/graph"), threads),
            tokens_per_step,
            || f.forward_loss(&batch).unwrap(),
        );

        // Realized overlap fraction under a simulated link: calibrate the
        // virtual clock against one (unsimulated) step, then measure how
        // much of the comm span union hides inside compute spans under
        // `--sched overlap` at two comm:compute ratios.
        let engine = NativeBackend::synthetic_with_ctx(
            base_ctx.with_sched(SchedMode::Overlap),
        );
        let mut cal = TpTrainer::new(
            &engine, "tiny", variant, 2, PCIE_GEN4, TrainConfig::default())
        .unwrap();
        cal.train_step(&batch).unwrap(); // warm
        let t0 = std::time::Instant::now();
        cal.train_step(&batch).unwrap();
        let step_secs = t0.elapsed().as_secs_f64();
        let ars = cal.ledger.stats().allreduces as f64 / 2.0; // per step
        let ar_bytes = (cal.batch * cfg.seq_len * cfg.d_model * 4) as f64;
        let ar_model = cal.ledger.allreduce_model_secs(ar_bytes);
        // Two operating points: comm ≈ 25% of a step (fully hideable —
        // predicted 1.0) and comm ≈ 2× a step (link-bound — predicted
        // well below 1.0), so the realized-vs-predicted scoreboard rows
        // track the model through a non-degenerate range. Fresh trainer
        // per point so the retained comm/compute spans cover exactly the
        // measured simulated step (no collapsed warm-step history).
        let base_scale = (step_secs / (ars * ar_model)).max(1.0);
        for (point, scale) in
            [("light", 0.25 * base_scale), ("commheavy", 2.0 * base_scale)]
        {
            // Each operating point runs twice: the exact tier drains each
            // all-reduce as ONE comm node (eager-release baseline), the
            // fast tier splits it into AR_CHUNKS chunk nodes whose drains
            // occupy separate worker lanes concurrently — the `_chunked`
            // rows, whose commheavy realized fraction is expected to beat
            // the unchunked row (the chunked-collective overlap win).
            for tier in [KernelTier::Exact, KernelTier::Fast] {
                let suffix =
                    if tier == KernelTier::Fast { "_chunked" } else { "" };
                let eng = NativeBackend::synthetic_with_ctx(
                    base_ctx.with_sched(SchedMode::Overlap).with_kernels(tier),
                );
                let mut t = TpTrainer::new(
                    &eng, "tiny", variant, 2, PCIE_GEN4,
                    TrainConfig::default())
                .unwrap();
                t.comm_sim_scale = scale.max(1.0);
                t.breakdown.retain_intervals(COMM_BUCKET);
                t.breakdown.retain_intervals(COMPUTE_BUCKET);
                t.train_step(&batch).unwrap();
                let comm = t.breakdown.get(COMM_BUCKET);
                let compute = t.breakdown.get(COMPUTE_BUCKET);
                let hidden =
                    t.breakdown.intersection_secs(COMM_BUCKET, COMPUTE_BUCKET);
                let realized = if comm > 0.0 { hidden / comm } else { 0.0 };
                let predicted = predicted_hidden_fraction(compute, comm);
                println!(
                    "{name}/{point}{suffix}: comm {:.2}ms / compute {:.2}ms \
                     per sim step — overlap fraction realized \
                     {realized:.3}, predicted {predicted:.3}",
                    comm * 1e3,
                    compute * 1e3
                );
                b.record_case(
                    &format!(
                        "tp2_tiny_overlap_fraction_realized_{point}{suffix}_{name}_t{threads}"
                    ),
                    CaseMeta::new(
                        "overlap_fraction",
                        &format!("tiny/{name}/{point}{suffix}/realized"),
                        threads,
                    ),
                    &[realized],
                    0.0,
                );
                if tier == KernelTier::Exact {
                    b.record_case(
                        &format!(
                            "tp2_tiny_overlap_fraction_predicted_{point}_{name}_t{threads}"
                        ),
                        CaseMeta::new(
                            "overlap_fraction",
                            &format!("tiny/{name}/{point}/predicted"),
                            threads,
                        ),
                        &[predicted],
                        0.0,
                    );
                }
            }
        }
    }
    // Executed pipeline fwd+bwd: gpipe vs 1f1b at the same (stages,
    // micro) point. Same cells, same bits — the scoreboard rows track
    // whether the 1F1B dependency structure costs (or saves) wall-clock
    // next to its memory win, and the bubble rows compare the realized
    // idle fraction against timemodel::pipeline_bubble_fraction.
    {
        let engine = NativeBackend::synthetic_with_ctx(
            base_ctx.with_sched(SchedMode::Graph),
        );
        let (stages, micro) = (2usize, 4usize);
        for sched in [PpSched::GPipe, PpSched::OneFOneB] {
            let mut p =
                PpTrainer::new(&engine, "tiny", stages, micro, PCIE_GEN4)
                    .unwrap();
            p.pp_sched = sched;
            p.train_step(&batch).unwrap(); // warm
            b.bench_case(
                &format!(
                    "pp2m4_tiny_train_step_{}_t{threads}_graph",
                    sched.name()
                ),
                CaseMeta::new(
                    "pp_train_step",
                    &format!("tiny/{}/graph", sched.name()),
                    threads,
                ),
                tokens_per_step,
                || p.train_step(&batch).unwrap().0,
            );
            // Bubble fraction on a fresh trainer so the per-device busy
            // buckets cover exactly the measured wall-clock window.
            let mut q =
                PpTrainer::new(&engine, "tiny", stages, micro, PCIE_GEN4)
                    .unwrap();
            q.pp_sched = sched;
            let t0 = std::time::Instant::now();
            for _ in 0..2 {
                q.train_step(&batch).unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let realized = q.realized_bubble_fraction(wall);
            let predicted = pipeline_bubble_fraction(stages, micro);
            println!(
                "pp/{}: bubble realized {realized:.3}, predicted \
                 {predicted:.3} (t{stages}m{micro}, {threads} threads; \
                 realized needs >= {stages} workers to mean idle devices), \
                 peak stashes {:?} (predicted {})",
                sched.name(),
                q.stash_peaks(),
                q.predicted_peak_stash(),
            );
            b.record_case(
                &format!(
                    "pp2m4_tiny_bubble_fraction_realized_{}_t{threads}",
                    sched.name()
                ),
                CaseMeta::new(
                    "pp_bubble_fraction",
                    &format!("tiny/{}/realized", sched.name()),
                    threads,
                ),
                &[realized],
                0.0,
            );
            b.record_case(
                &format!(
                    "pp2m4_tiny_bubble_fraction_predicted_{}_t{threads}",
                    sched.name()
                ),
                CaseMeta::new(
                    "pp_bubble_fraction",
                    &format!("tiny/{}/predicted", sched.name()),
                    threads,
                ),
                &[predicted],
                0.0,
            );
        }
    }
    println!("\n== summary ==\n{}", b.summary());
    println!("(comm-volume halving is asserted in tests/tp_equivalence.rs; \
              wall-clock here is CPU-execution bound — compare *_graph vs \
              *_serial vs *_overlap rows, and the overlap_fraction rows for \
              the comm-hiding trajectory)");
    match b.write_json_default() {
        Ok(path) => println!("scoreboard: {}", path.display()),
        Err(e) => eprintln!("warning: could not write scoreboard: {e}"),
    }
}
