//! End-to-end TP coordinator step bench (tiny config): the paper's central
//! comparison run live — Pre-LN (2 AR/block) vs FAL (1 AR/block) — with the
//! real sharded stage kernels on the native backend. Also times
//! forward-only (TTFT path). Runs with default features: no artifacts
//! needed.
//!
//! Cases are persisted to `BENCH_native.json` (override with
//! `FAL_BENCH_JSON`) alongside the runtime_hotpath scoreboard; the thread
//! count is whatever the backend's ExecCtx resolved to (`FAL_THREADS`).
//!
//! `cargo bench --bench tp_step`

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::runtime::{Backend, NativeBackend};
use fal::util::benchkit::{Bench, CaseMeta};

fn main() {
    let engine = NativeBackend::synthetic();
    let threads = engine.exec_ctx().threads();
    let cfg = engine.manifest().config("tiny").unwrap().clone();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 50_000, 1);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, 2);
    let batch = loader.fixed_batch(3);
    let tokens_per_step = (4 * cfg.seq_len) as f64;

    let mut b = Bench::from_env();
    for (variant, name) in
        [(Variant::PreLn, "preln"), (Variant::Fal, "fal")]
    {
        let mut t = TpTrainer::new(
            &engine, "tiny", variant, 2, PCIE_GEN4, TrainConfig::default())
        .unwrap();
        // Warm the stage executables.
        t.train_step(&batch).unwrap();
        // The thread count is part of the case name: write_json merges by
        // name, so runs at different FAL_THREADS must not clobber each
        // other's scoreboard rows.
        b.bench_case(
            &format!("tp2_tiny_train_step_{name}_t{threads}"),
            CaseMeta::new("tp_train_step", &format!("tiny/{name}"), threads),
            tokens_per_step,
            || t.train_step(&batch).unwrap().0,
        );
        let mut f = TpTrainer::new(
            &engine, "tiny", variant, 2, PCIE_GEN4, TrainConfig::default())
        .unwrap();
        f.forward_loss(&batch).unwrap();
        b.bench_case(
            &format!("tp2_tiny_forward_{name}_t{threads}"),
            CaseMeta::new("tp_forward", &format!("tiny/{name}"), threads),
            tokens_per_step,
            || f.forward_loss(&batch).unwrap(),
        );
    }
    println!("\n== summary ==\n{}", b.summary());
    println!("(comm-volume halving is asserted in tests/tp_equivalence.rs; \
              wall-clock here is CPU-execution bound)");
    match b.write_json_default() {
        Ok(path) => println!("scoreboard: {}", path.display()),
        Err(e) => eprintln!("warning: could not write scoreboard: {e}"),
    }
}
