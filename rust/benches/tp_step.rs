//! End-to-end TP coordinator step bench (tiny config): the paper's central
//! comparison run live — Pre-LN (2 AR/block) vs FAL (1 AR/block) — with the
//! real sharded stage kernels on the native backend, under both StageGraph
//! schedules (`serial` = the historical rank loop, `graph` = rank-parallel
//! shard nodes + MHA ∥ MLP branch fork in the fused FAL stage). Also times
//! forward-only (TTFT path). Runs with default features: no artifacts
//! needed.
//!
//! Cases are persisted to `BENCH_native.json` (override with
//! `FAL_BENCH_JSON`) alongside the runtime_hotpath scoreboard; the thread
//! count is whatever `FAL_THREADS` resolves to, and the schedule is part
//! of the case name so `*_graph` vs `*_serial` rows track the overlap
//! speedup across PRs.
//!
//! `cargo bench --bench tp_step`

use fal::config::{TrainConfig, Variant, PCIE_GEN4};
use fal::coordinator::tp_trainer::TpTrainer;
use fal::data::{Corpus, CorpusSpec, Loader};
use fal::runtime::{Backend, ExecCtx, NativeBackend, SchedMode};
use fal::util::benchkit::{Bench, CaseMeta};

fn main() {
    let base_ctx = ExecCtx::from_env();
    let threads = base_ctx.threads();
    let probe = NativeBackend::synthetic();
    let cfg = probe.manifest().config("tiny").unwrap().clone();
    let corpus =
        Corpus::generate(CorpusSpec::for_vocab(cfg.vocab_size), 50_000, 1);
    let loader = Loader::new(&corpus, cfg.seq_len, 4, 0.1, 2);
    let batch = loader.fixed_batch(3);
    let tokens_per_step = (4 * cfg.seq_len) as f64;

    let mut b = Bench::from_env();
    for (variant, name) in
        [(Variant::PreLn, "preln"), (Variant::Fal, "fal")]
    {
        // Train step under both schedules: the graph-vs-serial delta is
        // the rank-parallel + branch-fork overlap win.
        for sched in [SchedMode::Serial, SchedMode::Graph] {
            let engine =
                NativeBackend::synthetic_with_ctx(base_ctx.with_sched(sched));
            let mut t = TpTrainer::new(
                &engine, "tiny", variant, 2, PCIE_GEN4,
                TrainConfig::default())
            .unwrap();
            // Warm the stage executables.
            t.train_step(&batch).unwrap();
            // Thread count and schedule are part of the case name:
            // write_json merges by name, so runs at different FAL_THREADS
            // / schedules must not clobber each other's scoreboard rows.
            b.bench_case(
                &format!(
                    "tp2_tiny_train_step_{name}_t{threads}_{}",
                    sched.name()
                ),
                CaseMeta::new(
                    "tp_train_step",
                    &format!("tiny/{name}/{}", sched.name()),
                    threads,
                ),
                tokens_per_step,
                || t.train_step(&batch).unwrap().0,
            );
        }
        // Forward-only (TTFT) under the default graph schedule. The sched
        // suffix keeps this row from merge-colliding with the pre-sched
        // (serial-loop) measurements of earlier scoreboards.
        let engine =
            NativeBackend::synthetic_with_ctx(base_ctx.with_sched(SchedMode::Graph));
        let mut f = TpTrainer::new(
            &engine, "tiny", variant, 2, PCIE_GEN4, TrainConfig::default())
        .unwrap();
        f.forward_loss(&batch).unwrap();
        b.bench_case(
            &format!("tp2_tiny_forward_{name}_t{threads}_graph"),
            CaseMeta::new("tp_forward", &format!("tiny/{name}/graph"), threads),
            tokens_per_step,
            || f.forward_loss(&batch).unwrap(),
        );
    }
    println!("\n== summary ==\n{}", b.summary());
    println!("(comm-volume halving is asserted in tests/tp_equivalence.rs; \
              wall-clock here is CPU-execution bound — compare *_graph vs \
              *_serial rows for the overlap win)");
    match b.write_json_default() {
        Ok(path) => println!("scoreboard: {}", path.display()),
        Err(e) => eprintln!("warning: could not write scoreboard: {e}"),
    }
}
